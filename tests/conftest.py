"""Tier-1 test harness config: persistent XLA compilation cache.

A session-scoped autouse fixture enables the repo-local persistent
compile cache (repro.core.compile_cache) for the whole suite, so a repeat
``pytest`` run — locally or in CI with the cache directory restored —
pays tracing only and skips XLA compilation of every sweep program it has
seen before. Opt-outs:

- ``REPRO_COMPILE_CACHE=0`` in the environment disables it for the run;
- ``@pytest.mark.no_persistent_cache`` disables it for one test (tests
  that drive cache enable/disable themselves, or that assert on the
  process-wide hit/miss counters, must not race the ambient cache).
"""
import os

import pytest

from repro.core import compile_cache


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "no_persistent_cache: disable the persistent XLA compilation "
        "cache around this test (for tests that manage cache state or "
        "assert on the process-wide compile-accounting counters)")


@pytest.fixture(scope="session", autouse=True)
def persistent_compile_cache():
    """Warm every tier-1 run after the first: sweep-program executables
    land in the repo-local cache dir (JAX_COMPILATION_CACHE_DIR
    overrides) and are reloaded instead of recompiled."""
    if os.environ.get(compile_cache.DISABLE_ENV) == "0":
        yield None
        return
    yield compile_cache.enable()


@pytest.fixture(autouse=True)
def _no_persistent_cache_marker(request):
    """Honor @pytest.mark.no_persistent_cache: cache off for the test,
    restored afterwards (unless the whole session opted out)."""
    if request.node.get_closest_marker("no_persistent_cache") is None:
        yield
        return
    was_enabled = compile_cache.enabled()
    was_dir = compile_cache.cache_dir()
    compile_cache.disable()
    try:
        yield
    finally:
        if was_enabled:
            compile_cache.enable(was_dir)
