"""Lower a declarative Scenario to the array-native windowed env tables.

The union of every primitive's tick edges cuts the run into W maximal
windows over which all tables are constant; ``lower`` paints each primitive
onto the rows it covers (in Scenario order) and emits, as plain numpy:

  win_start[W]           first tick of each window (win_start[0] == 0)
  win_of_tick[n_ticks]   tick -> window row (precomputed, exact)
  alive[W, n], drop[W, n, n], extra_delay[W, n, n], nic_scale[W, n]

``netsim.build_env`` embeds these into the env dict; padding to a common
``n_windows`` (repeat-last-row, rows never read because ``win_of_tick``
only indexes real windows) is what lets heterogeneous scenarios stack
leaf-wise through ``netsim.stack_envs`` and vmap through
``experiment.run_sweep`` as one compiled program.

``from_fault_schedule`` compiles the seed-era ``netsim.FaultSchedule`` to
an equivalent Scenario: crash times become permanent ``Crash`` events and
the §5.5 DDoS becomes a random-minority ``TargetedDelay`` with the same
seeded draw stream, so the lowered tables reproduce the old per-tick
alive/link_delay values bitwise (pinned by tests/test_scenarios.py).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.smr import SMRConfig
from repro.scenarios.primitives import Crash, Scenario, Tables, TargetedDelay


def _sim_ticks(cfg: SMRConfig) -> int:
    # keep in sync with netsim.sim_ticks (not imported: scenarios sit below
    # core in the layering; netsim imports us lazily from build_env)
    return int(cfg.sim_seconds * 1000 / cfg.tick_ms)


def n_windows(cfg: SMRConfig, scenario: Scenario) -> int:
    """Window count of the lowered scenario (for cross-scenario padding)."""
    return len(_win_starts(cfg, scenario))


def _win_starts(cfg: SMRConfig, scenario: Scenario) -> np.ndarray:
    n_ticks = _sim_ticks(cfg)
    edges = {0}
    for ev in scenario.events:
        edges.update(int(e) for e in ev.edges(cfg, n_ticks))
    return np.array(sorted(e for e in edges if 0 <= e < n_ticks), np.int64)


def lower(cfg: SMRConfig, scenario: Scenario,
          pad_windows: Optional[int] = None) -> Tables:
    n = cfg.n_replicas
    n_ticks = _sim_ticks(cfg)
    win_start = _win_starts(cfg, scenario)
    w = len(win_start)
    tab: Tables = {
        "alive": np.ones((w, n), np.bool_),
        "drop": np.zeros((w, n, n), np.bool_),
        "extra_delay": np.zeros((w, n, n), np.float32),
        "nic_scale": np.ones((w, n), np.float32),
    }
    for ev in scenario.events:
        ev.paint(cfg, n_ticks, win_start, tab)
    if pad_windows is not None:
        if pad_windows < w:
            raise ValueError(f"pad_windows={pad_windows} < {w} real windows")
        pad = pad_windows - w
        tab = {k: np.pad(v, ((0, pad),) + ((0, 0),) * (v.ndim - 1),
                         mode="edge") for k, v in tab.items()}
    tab["win_start"] = win_start
    tab["win_of_tick"] = (np.searchsorted(win_start, np.arange(n_ticks),
                                          side="right") - 1).astype(np.int32)
    return tab


def from_fault_schedule(faults) -> Scenario:
    """Compatibility shim: compile a netsim.FaultSchedule to the equivalent
    Scenario (same crash semantics, same seeded DDoS draw stream)."""
    events = []
    if faults.crash_time_s is not None:
        for i, t_s in enumerate(np.asarray(faults.crash_time_s, np.float64)):
            if np.isfinite(t_s):
                # the seed-era check was t < float32(t_s * 1000 / tick_ms);
                # ceil of that value is the first dead tick either way
                events.append(Crash(start_s=float(t_s), targets=(i,)))
    if faults.ddos:
        events.append(TargetedDelay(
            delay_ms=faults.ddos_attack_delay_ms, targets="random-minority",
            repick_s=faults.ddos_repick_s, seed=faults.ddos_seed))
    return Scenario(name="fault-schedule", events=tuple(events))


def as_scenario(obj) -> Scenario:
    """Normalize None / Scenario / FaultSchedule to a Scenario."""
    if obj is None:
        return Scenario()
    if isinstance(obj, Scenario):
        return obj
    from repro.core.netsim import FaultSchedule
    if isinstance(obj, FaultSchedule):
        return from_fault_schedule(obj)
    raise TypeError(f"expected Scenario or FaultSchedule, got {type(obj)}")
