"""Quickstart: train a reduced SmolLM on CPU, checkpoint, resume, decode.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import serve
from repro.launch.train import train


def main() -> None:
    with tempfile.TemporaryDirectory() as d:
        print("== train (reduced smollm-135m) ==")
        out = train("smollm-135m", steps=60, batch=8, seq=32,
                    ckpt_dir=d, ckpt_every=30, lr=2e-3, log_every=15)
        print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
        assert out["losses"][-1] < out["losses"][0]
        print("== resume from checkpoint ==")
        train("smollm-135m", steps=80, batch=8, seq=32,
              ckpt_dir=d, ckpt_every=40, lr=2e-3, log_every=10)
    print("== decode ==")
    serve("smollm-135m", batch=2, prompt_len=8, gen=16)


if __name__ == "__main__":
    main()
