"""qwen3-14b — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab=151936, qk_norm=True,
    notes="qk-norm on per-head q/k",
)
