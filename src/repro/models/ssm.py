"""SSM mixers: Mamba selective scan, xLSTM mLSTM (matrix memory) and sLSTM.

Design notes (TPU adaptation):
- Mamba train path scans over chunks; within a chunk the recurrence runs as an
  associative scan on [B, L, Di, N] in fp32 — live memory is bounded by the
  chunk, never [B, S, Di, N]. The Pallas ``ssm_scan`` kernel implements the
  same contraction with the state resident in VMEM.
- mLSTM train path is the chunked linear-attention form with log-space
  gates: intra-chunk [L, L] decay-weighted scores + inter-chunk matrix state
  [dk, dv], with a cummax stabilizer (exponential input gate, sigmoid forget).
- sLSTM is inherently sequential (recurrent head mixing) -> lax.scan over S.
All mixers expose: init_*, *_forward (train), *_init_state, *_decode.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ===========================================================================
# Mamba
# ===========================================================================

def init_mamba(cfg: ModelConfig, key):
    s = cfg.ssm
    d, di, n, k = cfg.d_model, cfg.ssm.expand * cfg.d_model, s.d_state, s.d_conv
    ks = jax.random.split(key, 6)
    return {
        "w_in": jax.random.normal(ks[0], (d, 2 * di)) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (k, di)) * 0.2,
        "conv_b": jnp.zeros((di,)),
        "w_bc": jax.random.normal(ks[2], (di, 2 * n)) * di ** -0.5,
        "w_dt": jax.random.normal(ks[3], (di, 1)) * di ** -0.5,
        "dt_bias": jnp.full((di,), -3.0),     # softplus^-1(~0.05)
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                          (di, n)) + 0.0),
        "D": jnp.ones((di,)),
        "w_out": jax.random.normal(ks[4], (di, d)) * di ** -0.5,
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: [B,S,Di], w: [K,Di] depthwise causal conv."""
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for j in range(k):
        shift = k - 1 - j
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xs * w[j]
    return out + b


def _ssm_chunk_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Associative scan of h_t = a_t h_{t-1} + b_t over axis 1.
    a, b: [B, L, Di, N] fp32; h0: [B, Di, N]. Returns (h_all, h_last)."""
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_c, b_c = jax.lax.associative_scan(combine, (a, b), axis=1)
    return b_c, b_c[:, -1]


def mamba_ssm(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
              A: jax.Array, D: jax.Array, chunk: int, h0: jax.Array = None,
              use_kernel: bool = False) -> jax.Array:
    """Selective scan core. x, dt: [B,S,Di]; B, C: [B,S,N]; A: [Di,N]; D: [Di]."""
    if use_kernel:
        from repro.kernels.ssm_scan.ops import ssm_scan
        return ssm_scan(x, dt, B, C, A, D)
    if h0 is None and x.shape[1] % chunk == 0:
        return _selective_scan(x, dt, B, C, A, D, chunk)
    bsz, s, di = x.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)
    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    xp, dtp, Bp, Cp = (jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
                       for t in (x, dt, B, C))

    def body(h, xs):
        xc, dtc, Bc, Cc = xs                                 # [B,L,...]
        dtf = dtc.astype(jnp.float32)
        a = jnp.exp(dtf[..., None] * A)                      # [B,L,Di,N]
        bmat = (dtf * xc.astype(jnp.float32))[..., None] * Bc[:, :, None, :].astype(jnp.float32)
        h_all, h_last = _ssm_chunk_scan(a, bmat, h)
        y = jnp.einsum("blin,bln->bli", h_all, Cc.astype(jnp.float32))
        return h_last, y.astype(x.dtype)

    xs = tuple(t.reshape(bsz, nchunk, chunk, *t.shape[2:]).swapaxes(0, 1)
               for t in (xp, dtp, Bp, Cp))
    _, ys = jax.lax.scan(body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(bsz, nchunk * chunk, di)[:, :s]
    return y + x * D


# ---- custom VJP: backward recomputes within-chunk states from saved
# chunk-boundary states only ([B, S/L, Di, N] residuals, never [B,S,Di,N]) —
# the TPU analogue of the fused CUDA selective-scan backward.

def _chunks(t, nchunk, chunk):
    return t.reshape(t.shape[0], nchunk, chunk, *t.shape[2:]).swapaxes(0, 1)


def _ssm_fwd_core(x, dt, B, C, A, D, chunk):
    bsz, s, di = x.shape
    n = A.shape[1]
    nchunk = s // chunk

    def body(h, xs):
        xc, dtc, Bc, Cc = xs
        dtf = dtc.astype(jnp.float32)
        a = jnp.exp(dtf[..., None] * A)
        bmat = (dtf * xc.astype(jnp.float32))[..., None] * \
            Bc[:, :, None, :].astype(jnp.float32)
        h_all, h_last = _ssm_chunk_scan(a, bmat, h)
        y = jnp.einsum("blin,bln->bli", h_all, Cc.astype(jnp.float32))
        return h_last, (y.astype(x.dtype), h)

    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    xs = tuple(_chunks(t, nchunk, chunk) for t in (x, dt, B, C))
    _, (ys, h_starts) = jax.lax.scan(body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(bsz, s, di) + x * D
    return y, h_starts                       # h_starts: [nchunk, B, Di, N]


@partial(jax.custom_vjp, nondiff_argnums=(6,))
def _selective_scan(x, dt, B, C, A, D, chunk):
    y, _ = _ssm_fwd_core(x, dt, B, C, A, D, chunk)
    return y


def _sel_fwd(x, dt, B, C, A, D, chunk):
    y, h_starts = _ssm_fwd_core(x, dt, B, C, A, D, chunk)
    return y, (x, dt, B, C, A, D, h_starts)


def _sel_bwd(chunk, res, dy):
    x, dt, B, C, A, D, h_starts = res
    bsz, s, di = x.shape
    n = A.shape[1]
    nchunk = s // chunk
    Af = A.astype(jnp.float32)

    xs = tuple(_chunks(t, nchunk, chunk) for t in (x, dt, B, C, dy))

    def body(carry, inp):
        dh_carry, dA_acc = carry             # dh_carry = a_next1 * dh_next1
        xc, dtc, Bc, Cc, dyc, hs = inp
        dtf = dtc.astype(jnp.float32)
        xf = xc.astype(jnp.float32)
        Bf = Bc[:, :, None, :].astype(jnp.float32)
        a = jnp.exp(dtf[..., None] * Af)                     # [B,L,Di,N]
        bmat = (dtf * xf)[..., None] * Bf
        h_all, _ = _ssm_chunk_scan(a, bmat, hs)              # recompute
        h_prev = jnp.concatenate([hs[:, None], h_all[:, :-1]], axis=1)
        g = dyc.astype(jnp.float32)[..., None] * \
            Cc[:, :, None, :].astype(jnp.float32)            # [B,L,Di,N]
        g = g.at[:, -1].add(dh_carry)
        # reverse scan: dh_t = g_t + a_{t+1} dh_{t+1}
        a_shift = jnp.concatenate([a[:, 1:],
                                   jnp.zeros_like(a[:, :1])], axis=1)
        ar = jnp.flip(a_shift, axis=1)
        gr = jnp.flip(g, axis=1)

        def comb(u, w):
            a1, b1 = u
            a2, b2 = w
            return a1 * a2, a2 * b1 + b2

        _, dh_r = jax.lax.associative_scan(comb, (ar, gr), axis=1)
        dh = jnp.flip(dh_r, axis=1)                          # [B,L,Di,N]
        ddt = jnp.sum(dh * (a * Af * h_prev + (xf[..., None] * Bf)), axis=3)
        dx = jnp.sum(dh * dtf[..., None] * Bf, axis=3)
        dB = jnp.sum(dh * (dtf * xf)[..., None], axis=2)     # [B,L,N]
        dC = jnp.sum(dyc.astype(jnp.float32)[..., None] * h_all, axis=2)
        dA_acc = dA_acc + jnp.sum(dh * a * dtf[..., None] * h_prev,
                                  axis=(0, 1))
        dh_carry_out = a[:, 0] * dh[:, 0]
        return (dh_carry_out, dA_acc), (ddt, dx, dB, dC)

    dh0 = jnp.zeros((bsz, di, n), jnp.float32)
    dA0 = jnp.zeros((di, n), jnp.float32)
    rev = tuple(jnp.flip(t, axis=0) for t in (*xs, h_starts))
    (_, dA), (ddt_r, dx_r, dB_r, dC_r) = jax.lax.scan(
        body, (dh0, dA0), rev)

    def unrev(t):
        return jnp.flip(t, axis=0).swapaxes(0, 1).reshape(bsz, s, -1)

    ddt = unrev(ddt_r)
    dx = unrev(dx_r) + dy.astype(jnp.float32) * D
    dB = unrev(dB_r)
    dC = unrev(dC_r)
    dD = jnp.sum(dy.astype(jnp.float32) * x.astype(jnp.float32), axis=(0, 1))
    return (dx.astype(x.dtype), ddt.astype(dt.dtype), dB.astype(B.dtype),
            dC.astype(C.dtype), dA.astype(A.dtype), dD.astype(D.dtype))


_selective_scan.defvjp(_sel_fwd, _sel_bwd)


def mamba_forward(p, x, *, cfg: ModelConfig, use_kernel: bool = False) -> jax.Array:
    """x: [B,S,D] -> [B,S,D]."""
    s_cfg = cfg.ssm
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"]))
    bc = jnp.einsum("bsi,ie->bse", xc, p["w_bc"])
    B, C = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsi,ie->bse", xc, p["w_dt"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y = mamba_ssm(xc, dt, B, C, A, p["D"], s_cfg.chunk, use_kernel=use_kernel)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["w_out"])


def mamba_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    di = cfg.ssm.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.ssm.d_state), jnp.float32),
    }


def mamba_decode(p, x, state, *, cfg: ModelConfig):
    """x: [B,1,D] -> (y [B,1,D], state)."""
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xin, z = jnp.split(xz, 2, axis=-1)
    win = jnp.concatenate([state["conv"], xin], axis=1)      # [B,K,Di]
    xc = jax.nn.silu(jnp.einsum("bki,ki->bi", win, p["conv_w"]) + p["conv_b"])[:, None]
    new_conv = win[:, 1:]
    bc = jnp.einsum("bsi,ie->bse", xc, p["w_bc"])
    B, C = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsi,ie->bse", xc, p["w_dt"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtf = dt[:, 0].astype(jnp.float32)                        # [B,Di]
    a = jnp.exp(dtf[..., None] * A)                           # [B,Di,N]
    bmat = (dtf * xc[:, 0].astype(jnp.float32))[..., None] * B[:, 0, None, :].astype(jnp.float32)
    h = a * state["h"] + bmat
    y = jnp.einsum("bin,bn->bi", h, C[:, 0].astype(jnp.float32)).astype(x.dtype)
    y = (y + xc[:, 0] * p["D"])[:, None] * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["w_out"]), {"conv": new_conv, "h": h}


# ===========================================================================
# mLSTM (xLSTM matrix memory) — chunked linear attention with log-space gates
# ===========================================================================

def init_mlstm(cfg: ModelConfig, key):
    d = cfg.d_model
    di = 2 * d
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "wq": jax.random.normal(ks[0], (d, di)) * d ** -0.5,
        "wk": jax.random.normal(ks[1], (d, di)) * d ** -0.5,
        "wv": jax.random.normal(ks[2], (d, di)) * d ** -0.5,
        "w_i": jax.random.normal(ks[3], (d, h)) * d ** -0.5,
        "b_i": jnp.zeros((h,)),
        "w_f": jax.random.normal(ks[4], (d, h)) * d ** -0.5,
        "b_f": jnp.full((h,), 3.0),           # open forget gate at init
        "w_og": jax.random.normal(ks[5], (d, di)) * d ** -0.5,
        "b_og": jnp.zeros((di,)),
        "w_out": jax.random.normal(ks[6], (di, d)) * di ** -0.5,
    }


def _mlstm_gates(p, x):
    log_i = jnp.einsum("bsd,dh->bsh", x, p["w_i"]).astype(jnp.float32) + p["b_i"]
    f_raw = jnp.einsum("bsd,dh->bsh", x, p["w_f"]).astype(jnp.float32) + p["b_f"]
    log_f = -jax.nn.softplus(-f_raw)          # log sigmoid — bounded <= 0
    return log_i, log_f


def mlstm_forward(p, x, *, cfg: ModelConfig) -> jax.Array:
    """Chunked mLSTM. x: [B,S,D]."""
    bsz, s, d = x.shape
    h = cfg.n_heads
    di = 2 * d
    dh = di // h
    L = min(cfg.ssm.chunk, s)
    assert s % L == 0, (s, L)
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(bsz, s, h, dh)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(bsz, s, h, dh) / jnp.sqrt(jnp.float32(dh)).astype(x.dtype)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(bsz, s, h, dh)
    log_i, log_f = _mlstm_gates(p, x)          # [B,S,H]

    nchunk = s // L
    qc = q.reshape(bsz, nchunk, L, h, dh).swapaxes(0, 1)
    kc = k.reshape(bsz, nchunk, L, h, dh).swapaxes(0, 1)
    vc = v.reshape(bsz, nchunk, L, h, dh).swapaxes(0, 1)
    ic = log_i.reshape(bsz, nchunk, L, h).swapaxes(0, 1)
    fc = log_f.reshape(bsz, nchunk, L, h).swapaxes(0, 1)

    def body(carry, xs):
        C, n, m = carry                        # [B,H,dk,dv], [B,H,dk], [B,H]
        qb, kb, vb, ib, fb = xs
        cf = jnp.cumsum(fb, axis=1)            # [B,L,H] cumulative log f
        g = ib - cf                            # [B,L,H]
        gmax = jax.lax.cummax(g, axis=1)
        m_t = cf + jnp.maximum(m[:, None], gmax)        # [B,L,H]
        # intra-chunk decay-weighted scores
        w_log = (cf[:, :, None] - cf[:, None, :] + ib[:, None, :, :]
                 - m_t[:, :, None])            # [B,L(t),L(tau),H]
        causal = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(causal[None, :, :, None], jnp.exp(w_log), 0.0)
        qk = jnp.einsum("blhe,bthe->blth", qb.astype(jnp.float32),
                        kb.astype(jnp.float32))
        num_intra = jnp.einsum("blth,blth,bthe->blhe", qk, w,
                               vb.astype(jnp.float32))
        den_intra = jnp.einsum("blth,blth->blh", qk, w)
        # inter-chunk (initial state) contribution
        scale = jnp.exp(m[:, None] + cf - m_t)           # [B,L,H]
        qC = jnp.einsum("blhe,bhef->blhf", qb.astype(jnp.float32), C)
        num = num_intra + scale[..., None] * qC
        den = den_intra + scale * jnp.einsum("blhe,bhe->blh",
                                             qb.astype(jnp.float32), n)
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # end-of-chunk state
        m_new = m_t[:, -1]                     # [B,H]
        s_dec = jnp.exp(m[:, None] + cf[:, -1:] - m_new[:, None])[:, 0]  # [B,H]
        k_w = jnp.exp(cf[:, -1:, :] - cf + ib - m_new[:, None])          # [B,L,H]
        C_new = s_dec[..., None, None] * C + jnp.einsum(
            "blh,blhe,blhf->bhef", k_w, kb.astype(jnp.float32),
            vb.astype(jnp.float32))
        n_new = s_dec[..., None] * n + jnp.einsum(
            "blh,blhe->bhe", k_w, kb.astype(jnp.float32))
        return (C_new, n_new, m_new), y.astype(x.dtype)

    C0 = jnp.zeros((bsz, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((bsz, h, dh), jnp.float32)
    m0 = jnp.full((bsz, h), -1e30, jnp.float32)
    _, ys = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, ic, fc))
    y = ys.swapaxes(0, 1).reshape(bsz, s, di)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["w_og"]) + p["b_og"])
    return jnp.einsum("bsi,id->bsd", y * og, p["w_out"])


def mlstm_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    h = cfg.n_heads
    dh = 2 * cfg.d_model // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(p, x, state, *, cfg: ModelConfig):
    """x: [B,1,D]."""
    bsz, _, d = x.shape
    h = cfg.n_heads
    di = 2 * d
    dh = di // h
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(bsz, h, dh).astype(jnp.float32)
    k = (jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(bsz, h, dh)
         / jnp.sqrt(jnp.float32(dh))).astype(jnp.float32)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(bsz, h, dh).astype(jnp.float32)
    log_i, log_f = _mlstm_gates(p, x)
    log_i, log_f = log_i[:, 0], log_f[:, 0]    # [B,H]
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + state["m"] - m_new)
    C = f_p[..., None, None] * state["C"] + i_p[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = f_p[..., None] * state["n"] + i_p[..., None] * k
    num = jnp.einsum("bhe,bhef->bhf", q, C)
    den = jnp.einsum("bhe,bhe->bh", q, n)
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["w_og"]) + p["b_og"])
    out = jnp.einsum("bsi,id->bsd", y * og, p["w_out"])
    return out, {"C": C, "n": n, "m": m_new}


# ===========================================================================
# sLSTM — sequential scalar LSTM with exponential gating + head mixing
# ===========================================================================

def init_slstm(cfg: ModelConfig, key):
    d = cfg.d_model
    di = 2 * d
    h = cfg.n_heads
    dh = di // h
    ks = jax.random.split(key, 6)
    p = {"w_out": jax.random.normal(ks[4], (di, d)) * di ** -0.5}
    for name, kk in zip(("i", "f", "z", "o"), jax.random.split(ks[0], 4)):
        p[f"w_{name}"] = jax.random.normal(kk, (d, di)) * d ** -0.5
        p[f"b_{name}"] = jnp.full((di,), 3.0) if name == "f" else jnp.zeros((di,))
    for name, kk in zip(("i", "f", "z", "o"), jax.random.split(ks[1], 4)):
        p[f"r_{name}"] = jax.random.normal(kk, (h, dh, dh)) * dh ** -0.5
    return p


def _slstm_step(p, h_cfg, carry, xproj):
    """carry: (c, n, h, m) each [B,Di]; xproj: dict of [B,Di] projections."""
    nheads, dh = h_cfg
    c, n, hh, m = carry
    hheads = hh.reshape(hh.shape[0], nheads, dh)

    def rec(name):
        return jnp.einsum("bhe,hef->bhf", hheads,
                          p[f"r_{name}"].astype(hh.dtype)).reshape(hh.shape)

    i_raw = (xproj["i"] + rec("i")).astype(jnp.float32)
    f_raw = (xproj["f"] + rec("f")).astype(jnp.float32)
    z = jnp.tanh((xproj["z"] + rec("z")).astype(jnp.float32))
    o = jax.nn.sigmoid((xproj["o"] + rec("o")).astype(jnp.float32))
    log_f = -jax.nn.softplus(-f_raw)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_p = jnp.exp(i_raw - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = (o * c_new / jnp.maximum(n_new, 1.0)).astype(hh.dtype)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(p, x, *, cfg: ModelConfig) -> jax.Array:
    bsz, s, d = x.shape
    di = 2 * d
    h, dh = cfg.n_heads, di // cfg.n_heads
    xproj = {name: jnp.einsum("bsd,de->bse", x, p[f"w_{name}"]) + p[f"b_{name}"]
             for name in ("i", "f", "z", "o")}
    c0 = jnp.zeros((bsz, di), jnp.float32)
    st0 = (c0, c0, jnp.zeros((bsz, di), x.dtype), jnp.full((bsz, di), -1e30, jnp.float32))

    def body(carry, xs):
        return _slstm_step(p, (h, dh), carry, xs)

    xs = {k_: v.swapaxes(0, 1) for k_, v in xproj.items()}   # [S,B,Di]
    _, hs = jax.lax.scan(body, st0, xs)
    y = hs.swapaxes(0, 1)                                    # [B,S,Di]
    return jnp.einsum("bsi,id->bsd", y, p["w_out"])


def slstm_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    di = 2 * cfg.d_model
    return {
        "c": jnp.zeros((batch, di), jnp.float32),
        "n": jnp.zeros((batch, di), jnp.float32),
        "h": jnp.zeros((batch, di), dtype),
        "m": jnp.full((batch, di), -1e30, jnp.float32),
    }


def slstm_decode(p, x, state, *, cfg: ModelConfig):
    di = 2 * cfg.d_model
    h, dh = cfg.n_heads, di // cfg.n_heads
    xproj = {name: jnp.einsum("bsd,de->bse", x, p[f"w_{name}"])[:, 0] + p[f"b_{name}"]
             for name in ("i", "f", "z", "o")}
    carry = (state["c"], state["n"], state["h"], state["m"])
    (c, n, hh, m), y = _slstm_step(p, (h, dh), carry, xproj)
    out = jnp.einsum("bsi,id->bsd", y[:, None], p["w_out"])
    return out, {"c": c, "n": n, "h": hh, "m": m}
