"""Jit'd wrapper: layout handling, padding, CPU-interpret fallback."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: [B, S, H, D]; k, v: [B, S, Kh, D] (model layout). Returns same.

    Pads S up to a block multiple; extra KV rows are masked out by the causal
    mask (queries in padding are discarded on return).
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, s, h, d = q.shape
    bq = min(bq, max(16, 1 << (s - 1).bit_length()))
    bk = min(bk, bq)
    pad = (-s) % bq
    if pad:
        cfgpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, cfgpad)
        k = jnp.pad(k, cfgpad)
        v = jnp.pad(v, cfgpad)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, bq=bq, bk=bk,
                               interpret=interpret)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :s] if pad else out
