"""Core layers: RMSNorm, RoPE, GQA attention (dense + chunked/online-softmax),
SwiGLU MLP.  Pure JAX; Pallas kernels are selected via ``CallConfig``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def constrain_act(x: jax.Array, call: "CallConfig") -> jax.Array:
    """Apply the policy's activation sharding (needs an active mesh ctx)."""
    if not call.batch_axes and call.seq_axis is None:
        return x
    spec = [None] * x.ndim
    if call.batch_axes:
        spec[0] = call.batch_axes
    if call.seq_axis is not None and x.ndim >= 3:
        spec[1] = call.seq_axis
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:       # no mesh context (CPU tests): no-op
        return x


@dataclass(frozen=True)
class CallConfig:
    """How to execute the model (orthogonal to what the model is)."""
    compute_dtype: jnp.dtype = jnp.bfloat16
    # attention implementation: "dense" materializes [S,S] scores (XLA default),
    # "chunked" streams KV blocks with online softmax (flash-style, O(S) memory),
    # "pallas" uses the TPU kernel (validated in interpret mode on CPU).
    attention_impl: str = "dense"
    attn_chunk: int = 512
    use_pallas_norm: bool = False
    remat: bool = True
    # decode: KV-cache sequence sharding needs positions masked per shard
    decode_chunked: bool = False
    # ---- sharding-policy knobs (hillclimbs; see EXPERIMENTS.md §Perf) ----
    # constrain activations [B, S, D] to P(batch_axes, seq_axis, None)
    batch_axes: Tuple[str, ...] = ()
    seq_axis: Optional[str] = None          # sequence parallelism
    # expand KV to full heads before attention (kv projections replicated,
    # q heads TP-aligned -> no GQA resharding collectives)
    gqa_expand_kv: bool = False
    # MoE expert-parallel axis for dispatch all-to-alls (None = SPMD default)
    moe_ep_axis: Optional[str] = None
    moe_group_size: int = 1024


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5,
             call: Optional[CallConfig] = None) -> jax.Array:
    if call is not None and call.use_pallas_norm and x.ndim >= 2:
        from repro.kernels.rmsnorm.ops import rmsnorm as pl_rmsnorm
        return pl_rmsnorm(x, w, eps=eps)
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w


def head_rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """qk-norm: normalize over the head dim. x: [..., Dh], w: [Dh]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w).astype(x.dtype)


# ---------------------------------------------------------------------------
# rope
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, Dh]; positions: [B, S] (or [S])."""
    freqs = rope_freqs(x.shape[-1], theta)                  # [Dh/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, Dh/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

def _gqa_expand(q: jax.Array, n_kv: int) -> jax.Array:
    """[B,S,H,D] -> [B,S,Kh,G,D]."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, q_pos: Optional[jax.Array] = None,
                    kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Reference attention, materializes scores.

    q: [B,Sq,H,D], k/v: [B,Sk,Kh,D].  GQA by head grouping.
    ``kv_len``: optional [B] or scalar — mask cache positions >= kv_len.
    ``q_pos``: positions of the queries (for causal masking vs absolute kv idx).
    """
    b, sq, h, d = q.shape
    kh = k.shape[2]
    qg = _gqa_expand(q, kh)                               # [B,Sq,Kh,G,D]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bqkgd,btkd->bkgqt", qg, k).astype(jnp.float32) * scale
    t_idx = jnp.arange(k.shape[1])
    if causal:
        qp = q_pos if q_pos is not None else jnp.arange(sq)
        mask = t_idx[None, :] <= qp[:, None]              # [Sq, Sk]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    if kv_len is not None:
        kvl = jnp.broadcast_to(jnp.asarray(kv_len), (b,))
        valid = t_idx[None, :] < kvl[:, None]             # [B, Sk]
        logits = jnp.where(valid[:, None, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p, v)
    return out.reshape(b, sq, h, d)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk: int = 512,
                      q_pos: Optional[jax.Array] = None,
                      kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Flash-style online-softmax over KV chunks — O(Sq·chunk) live memory.

    Used (a) as the XLA-lowerable flash path for the dry-run and (b) as the
    long-context decode attention. Same signature as dense_attention.
    """
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    nchunk = -(-sk // chunk)
    pad = nchunk * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunk, chunk, kh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunk, chunk, kh, d).transpose(1, 0, 2, 3, 4)
    qg = _gqa_expand(q, kh)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qp = q_pos if q_pos is not None else jnp.arange(sq)
    kvl = None if kv_len is None else jnp.asarray(kv_len).reshape(-1)

    def body(carry, xs):
        acc, m, l = carry
        (kb, vb), ci = xs
        t_idx = ci * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bqkgd,btkd->bkgqt", qg, kb).astype(jnp.float32) * scale
        neg = jnp.float32(-1e30)
        # additive bias on small shapes — never materialize a full-shape mask
        if causal:
            bias = jnp.where(t_idx[None, :] <= qp[:, None], 0.0, neg)  # [q,t]
            logits = logits + bias[None, None, None]
        if kvl is not None or pad:
            vl = jnp.full((b,), sk) if kvl is None else kvl
            vbias = jnp.where(t_idx[None, :] < vl[:, None], 0.0, neg)  # [b,t]
            logits = logits + vbias[:, None, None, None]
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(vb.dtype), vb)
        acc = acc * corr[..., None] + pv.astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, kh, h // kh, sq, d), jnp.float32)
    m0 = jnp.full((b, kh, h // kh, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kh, h // kh, sq), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  ((kc, vc), jnp.arange(nchunk)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


# ---- flash-style custom VJP: backward recomputes per-chunk probabilities
# from (q, k, v, out, lse) instead of saving scan residuals — O(S·chunk)
# live memory in both directions (the memory story of FlashAttention).

def _chunk_fwd_lse(q, k, v, *, causal: bool, chunk: int):
    """Forward returning (out, lse). Shapes as chunked_attention."""
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    nchunk = sk // chunk
    kc = k.reshape(b, nchunk, chunk, kh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunk, chunk, kh, d).transpose(1, 0, 2, 3, 4)
    qg = _gqa_expand(q, kh)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qp = jnp.arange(sq)

    def body(carry, xs):
        acc, m, l = carry
        (kb, vb), ci = xs
        t_idx = ci * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bqkgd,btkd->bkgqt", qg, kb).astype(jnp.float32) * scale
        if causal:
            bias = jnp.where(t_idx[None, :] <= qp[:, None], 0.0, -1e30)
            logits = logits + bias[None, None, None]
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        # materialize probabilities at compute precision: the [q, t] tile is
        # the dominant HBM tensor on the XLA path (stays in VMEM in the
        # Pallas kernel); sum/max stats stay fp32
        pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l), None

    g = h // kh
    acc0 = jnp.zeros((b, kh, g, sq, d), jnp.float32)
    m0 = jnp.full((b, kh, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  ((kc, vc), jnp.arange(nchunk)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = (acc / jnp.maximum(l[..., None], 1e-30))
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)
    return out, lse


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_chunked(q, k, v, causal: bool, chunk: int):
    out, _ = _chunk_fwd_lse(q, k, v, causal=causal, chunk=chunk)
    return out


def _flash_fwd(q, k, v, causal, chunk):
    out, lse = _chunk_fwd_lse(q, k, v, causal=causal, chunk=chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, chunk, res, dout):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    nchunk = sk // chunk
    qg = _gqa_expand(q, kh).astype(jnp.float32)            # [b,q,kh,g,d]
    dog = _gqa_expand(dout, kh).astype(jnp.float32)
    og = _gqa_expand(out, kh).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qp = jnp.arange(sq)
    # D_i = rowsum(dout * out)
    delta = jnp.einsum("bqkgd,bqkgd->bkgq", dog, og)       # [b,kh,g,q]
    kc = k.reshape(b, nchunk, chunk, kh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunk, chunk, kh, d).transpose(1, 0, 2, 3, 4)

    def body(dq_acc, xs):
        (kb, vb), ci = xs
        t_idx = ci * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bqkgd,btkd->bkgqt", qg, kb.astype(jnp.float32)) * scale
        if causal:
            bias = jnp.where(t_idx[None, :] <= qp[:, None], 0.0, -1e30)
            logits = logits + bias[None, None, None]
        cdt = kb.dtype
        p = jnp.exp(logits - lse[..., None]).astype(cdt)   # [b,kh,g,q,t]
        dv = jnp.einsum("bkgqt,bqkgd->btkd", p, dog.astype(cdt),
                        preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqkgd,btkd->bkgqt", dog.astype(cdt), vb,
                        preferred_element_type=jnp.float32)
        ds = (p.astype(jnp.float32) * (dp - delta[..., None]) * scale
              ).astype(cdt)
        dq_acc = dq_acc + jnp.einsum("bkgqt,btkd->bqkgd", ds, kb,
                                     preferred_element_type=jnp.float32)
        dk = jnp.einsum("bkgqt,bqkgd->btkd", ds, qg.astype(cdt),
                        preferred_element_type=jnp.float32)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((b, sq, kh, g, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0,
                                  ((kc, vc), jnp.arange(nchunk)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, sk, kh, d)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, sk, kh, d)
    return (dq.reshape(b, sq, h, d).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


flash_chunked.defvjp(_flash_fwd, _flash_bwd)


def attention_core(q, k, v, *, causal, call: CallConfig,
                   q_pos=None, kv_len=None) -> jax.Array:
    full_self = causal and kv_len is None and q_pos is None \
        and q.shape[1] == k.shape[1]
    if call.attention_impl == "pallas" and full_self:
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, causal=True)
    if call.attention_impl in ("chunked", "pallas"):
        if full_self and k.shape[1] % call.attn_chunk == 0:
            return flash_chunked(q, k, v, True, call.attn_chunk)
        return chunked_attention(q, k, v, causal=causal, chunk=call.attn_chunk,
                                 q_pos=q_pos, kv_len=kv_len)
    return dense_attention(q, k, v, causal=causal, q_pos=q_pos, kv_len=kv_len)


# ---------------------------------------------------------------------------
# attention layer (self + cross), with KV cache for decode
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, cross: bool = False):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    std = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, qd)) * std,
        "wk": jax.random.normal(k2, (d, kvd)) * std,
        "wv": jax.random.normal(k3, (d, kvd)) * std,
        "wo": jax.random.normal(k4, (qd, d)) * (qd ** -0.5),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((qd,)); p["bk"] = jnp.zeros((kvd,)); p["bv"] = jnp.zeros((kvd,))
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((cfg.head_dim,)); p["k_norm"] = jnp.ones((cfg.head_dim,))
    return p


def self_attention(p, x, *, cfg: ModelConfig, call: CallConfig,
                   positions, cache: Optional[dict] = None,
                   max_seq: Optional[int] = None) -> Tuple[jax.Array, Optional[dict]]:
    """x: [B,S,D]. Train/prefill: cache=None (prefill may still return one).
    Decode: S==1 with cache {'k','v'} of [B, Smax, Kh, Dh] and positions [B] or scalar.
    """
    b, s, _ = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kh, dh)
    v = v.reshape(b, s, kh, dh)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if call.gqa_expand_kv and kh < h:
        # replicate KV heads up front: attention becomes head-parallel with
        # no [Kh, G] resharding (kv projections are small and replicated)
        k = jnp.repeat(k, h // kh, axis=2)
        v = jnp.repeat(v, h // kh, axis=2)
        kh = h
    pos2d = positions if positions.ndim > 0 else positions[None]
    q = apply_rope(q, jnp.broadcast_to(pos2d.reshape(1, -1) if pos2d.ndim == 1
                                       else pos2d, (b, s)), cfg.rope_theta)
    k = apply_rope(k, jnp.broadcast_to(pos2d.reshape(1, -1) if pos2d.ndim == 1
                                       else pos2d, (b, s)), cfg.rope_theta)

    new_cache = None
    if cache is not None and s == 1:          # decode
        pos = positions.reshape(())            # scalar position
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        out = attention_core(q, ck, cv, causal=False, call=call,
                             kv_len=pos + 1)
    else:                                      # train / prefill
        out = attention_core(q, k, v, causal=True, call=call)
        if max_seq is not None:               # prefill: build cache
            pad = max_seq - s
            new_cache = {
                "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            }
    out = out.reshape(b, s, h * dh)
    return jnp.einsum("bse,ed->bsd", out, p["wo"]), new_cache


def cross_attention(p, x, mem, *, cfg: ModelConfig, call: CallConfig) -> jax.Array:
    """x: [B,S,D], mem: [B,M,D] (stubbed modality embeddings)."""
    b, s, _ = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bmd,de->bme", mem, p["wk"]).reshape(b, -1, kh, dh)
    v = jnp.einsum("bmd,de->bme", mem, p["wv"]).reshape(b, -1, kh, dh)
    out = attention_core(q, k, v, causal=False, call=call)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, h * dh), p["wo"])


# ---------------------------------------------------------------------------
# mlp
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "w_gate": jax.random.normal(k1, (d, d_ff)) * d ** -0.5,
        "w_up": jax.random.normal(k2, (d, d_ff)) * d ** -0.5,
        "w_down": jax.random.normal(k3, (d_ff, d)) * d_ff ** -0.5,
    }


def swiglu(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"])
