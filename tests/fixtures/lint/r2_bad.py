"""R2 dtype-hygiene: f64 creep toward simulator buffers."""
import numpy as np


def widen(x):
    return np.asarray(x, dtype=np.float64)  # expect: R2
