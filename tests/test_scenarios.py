"""Scenario engine (repro/scenarios): primitive -> windowed-table lowering
(pinned bitwise against the seed-era fault-model reference, so the fig 6-9
artifacts are unchanged by the netsim refactors), the auto-sized channel
delay horizon, scenario grids batching through run_sweep as ONE compiled
program, and the partition semantics the paper's robustness story hinges
on (a cut minority stops committing; a healed one catches up)."""
import math

import numpy as np
import pytest

from repro.configs.smr import SMRConfig
from repro.core import experiment, netsim
from repro.core.experiment import SweepSpec, run_sweep
from repro.core.harness import run_sim
from repro.scenarios import (
    BandwidthThrottle,
    Crash,
    GrayFailure,
    Partition,
    Recover,
    Scenario,
    TargetedDelay,
    as_scenario,
    library,
    lower,
)

CFG = SMRConfig(sim_seconds=2.0)
N = CFG.n_replicas


# ------------------------------------------- seed-era fault semantics ----

def test_ddos_tables_match_seed_era_reference_bitwise():
    """The random-minority TargetedDelay reproduces the seed-era per-tick
    link_delay — same seeded attacked-minority stream, same float32
    arithmetic — which is what keeps the fig 6-9 artifacts bitwise
    identical across the fault-model rewrites."""
    sc = Scenario("ddos", (TargetedDelay(
        delay_ms=800.0, targets="random-minority", repick_s=0.5, seed=7),))
    env = netsim.build_env(CFG, sc)
    # seed-era reference, computed the way the old netsim did
    rng = np.random.RandomState(7)
    repick = max(1, int(0.5 * 1000 / CFG.tick_ms))
    w = int(np.ceil(CFG.sim_seconds / 0.5)) + 1
    att = np.zeros((w, N), bool)
    for k in range(w):
        att[k, rng.choice(N, size=(N - 1) // 2, replace=False)] = True
    delays = np.asarray(CFG.delays_ms() / CFG.tick_ms, np.float32)
    dd = np.float32(800.0 / CFG.tick_ms)
    for t in (0, 1, 499, 500, 999, 1000, 1500, 1999):
        a = att[min(t // repick, w - 1)]
        ref = delays + (a[:, None] | a[None, :]) * dd
        np.testing.assert_array_equal(np.asarray(netsim.link_delay(env, t)),
                                      ref, err_msg=f"t={t}")
        assert np.asarray(netsim.link_drop(env, t)).sum() == 0


def test_crash_tables_match_seed_era_reference_bitwise():
    crash = np.full(N, np.inf)
    crash[0], crash[3] = 0.7, 1.2345
    sc = Scenario("crash", tuple(
        Crash(start_s=float(t), targets=(i,))
        for i, t in enumerate(crash) if np.isfinite(t)))
    env = netsim.build_env(CFG, sc)
    crash_tick = crash * 1000.0 / CFG.tick_ms
    for t in (0, 699, 700, 701, 1234, 1235, 1999):
        np.testing.assert_array_equal(np.asarray(netsim.alive(env, t)),
                                      t < crash_tick, err_msg=f"t={t}")


# ------------------------------------------------- auto delay horizon ----

def test_auto_horizon_covers_library_scenarios():
    """The resolved ring size strictly exceeds the largest static link +
    scenario delay for every curated adversary (any delivered message fits
    without clipping), and is a power of two."""
    lib = library.scenarios(CFG.sim_seconds, N)
    static = float(np.max(CFG.delays_ms()) / CFG.tick_ms)
    for name, sc in lib.items():
        cfg = netsim.resolve_horizon(CFG, (sc,))
        h = cfg.delay_horizon_ticks
        assert h & (h - 1) == 0, f"{name}: horizon {h} not a power of two"
        extra = float(np.max(lower(CFG, sc)["extra_delay"], initial=0.0))
        assert h > static + extra, \
            f"{name}: horizon {h} <= static delay bound {static + extra}"


def test_auto_horizon_matches_seed_era_2048_end_to_end():
    """run_sim with the auto-sized ring == run_sim with the seed-era fixed
    2048 ring, bit for bit — shrinking the horizon must never change what
    gets delivered (this is what keeps the fig 6-9 artifacts identical)."""
    import dataclasses
    cfg = SMRConfig(sim_seconds=1.0)
    assert cfg.delay_horizon_ticks == "auto"
    pinned = dataclasses.replace(cfg, delay_horizon_ticks=2048)
    ddos = Scenario("ddos", (TargetedDelay(
        delay_ms=800.0, targets="random-minority", repick_s=0.5, seed=7),))
    for proto, scenario in (("mandator-sporades", None),
                            ("mandator-sporades", ddos),
                            ("multipaxos", None)):
        a = run_sim(proto, cfg, rate_tx_s=30_000, scenario=scenario)
        b = run_sim(proto, pinned, rate_tx_s=30_000, scenario=scenario)
        for k in ("throughput", "median_ms", "p99_ms", "committed"):
            assert a[k] == b[k] or (np.isnan(a[k]) and np.isnan(b[k])), \
                (proto, k, a[k], b[k])
        np.testing.assert_array_equal(a["timeline"], b["timeline"])


def test_canonical_signature_is_bitwise_inert():
    """Property-style canonicalization pin: any sweep whose resolved
    horizon fits the canonical Dmax yields byte-identical metrics whether
    lowered at its native signature (exact lanes/windows/horizon) or the
    canonical one (lanes, window tables, and horizon padded up). Cases
    cover the padding axes separately: batch lanes (multi-rate baseline),
    scenario windows (crash schedule), and both at once."""
    cfg = SMRConfig(sim_seconds=0.5)
    crash = Scenario("crash", (Crash(start_s=0.25, targets=(0,)),))
    cases = (
        ("mandator-sporades", SweepSpec(rates=(10_000, 30_000))),
        ("mandator-sporades", SweepSpec(rates=(20_000,),
                                        scenarios=(crash,))),
        ("multipaxos", SweepSpec(rates=(10_000, 30_000),
                                 scenarios=(None, crash))),
    )
    for proto, spec in cases:
        native = run_sweep(proto, cfg, spec, canonical=False)
        canon = run_sweep(proto, cfg, spec, canonical=True)
        for a, b in zip(native, canon):
            for k in ("throughput", "median_ms", "p99_ms", "committed"):
                assert a[k] == b[k] or (np.isnan(a[k]) and np.isnan(b[k])), \
                    (proto, k, a[k], b[k])
            np.testing.assert_array_equal(a["timeline"], b["timeline"])
            if proto == "mandator-sporades":
                np.testing.assert_array_equal(a["cvc_all"], b["cvc_all"])


def test_canonical_floor_only_rounds_auto_horizons():
    """resolve_horizon(canonical=True) floors an auto horizon at the
    canonical Dmax but never touches a pinned (int) horizon."""
    import dataclasses
    small = dataclasses.replace(CFG, sim_seconds=0.5)
    auto = netsim.resolve_horizon(small, (None,), canonical=True)
    assert auto.delay_horizon_ticks >= netsim.CANONICAL_HORIZON
    pinned = dataclasses.replace(CFG, delay_horizon_ticks=64)
    assert netsim.resolve_horizon(
        pinned, (None,), canonical=True).delay_horizon_ticks == 64
    # canonical never shrinks a larger-than-canonical auto bound
    ddos = Scenario("ddos", (TargetedDelay(
        delay_ms=800.0, targets="random-minority", repick_s=0.5, seed=7),))
    big = netsim.resolve_horizon(CFG, (ddos,), canonical=True)
    assert big.delay_horizon_ticks >= 1024


# ------------------------------------------------------------- lowering ----

def test_crash_interval_and_recover():
    """Crash is an interval (not a one-way trip); a later Recover wins."""
    sc = Scenario("x", (Crash(0.5, targets=(1,), end_s=1.0),
                        Crash(1.5, targets=(2,)),
                        Recover(1.8, targets=(2,))))
    env = netsim.build_env(CFG, sc)
    up = lambda t: np.asarray(netsim.alive(env, t))  # noqa: E731
    assert up(499).all()
    assert up(500).tolist() == [True, False, True, True, True]
    assert up(999).tolist() == [True, False, True, True, True]
    assert up(1000).all()
    assert up(1500).tolist() == [True, True, False, True, True]
    assert up(1800).all()


def test_targeted_delay_fixed_targets_and_throttle():
    sc = Scenario("x", (TargetedDelay(delay_ms=100.0, targets="leader",
                                      start_s=0.5, end_s=1.0),
                        BandwidthThrottle(1.0, math.inf, scale=0.25,
                                          targets=(3,))))
    env = netsim.build_env(CFG, sc)
    base = np.asarray(CFG.delays_ms() / CFG.tick_ms, np.float32)
    d0 = np.asarray(netsim.link_delay(env, 0))
    d7 = np.asarray(netsim.link_delay(env, 700))
    np.testing.assert_array_equal(d0, base)
    extra = np.zeros((N, N), np.float32)
    extra[0, :] = extra[:, 0] = 100.0
    np.testing.assert_array_equal(d7, base + extra)
    full = float(np.asarray(netsim.nic_rate(env, 0))[3])
    throttled = np.asarray(netsim.nic_rate(env, 1500))
    assert throttled[3] == pytest.approx(full * 0.25)
    assert (throttled[[0, 1, 2, 4]] == full).all()


def test_gray_failure_deterministic_and_bounded():
    sc = Scenario("g", (GrayFailure(0.0, 2.0, loss=0.2, jitter_ms=30.0,
                                    redraw_s=0.25, seed=5),))
    t1 = lower(CFG, sc)
    t2 = lower(CFG, sc)
    for k in ("drop", "extra_delay", "alive", "nic_scale", "win_of_tick"):
        np.testing.assert_array_equal(t1[k], t2[k])
    assert t1["extra_delay"].max() <= 30.0 / CFG.tick_ms
    assert not t1["drop"].diagonal(axis1=1, axis2=2).any(), \
        "gray failure must never cut self-links"
    frac = t1["drop"][:, ~np.eye(N, dtype=bool)].mean()
    assert 0.05 < frac < 0.5  # ~loss, across windows and links


def test_static_delay_over_horizon_rejected():
    """A pinned (int) horizon below the static delay is a hard error; the
    "auto" default would instead absorb it by growing the ring."""
    import dataclasses
    pinned = dataclasses.replace(CFG, delay_horizon_ticks=2048)
    with pytest.raises(ValueError, match="delay_horizon_ticks"):
        netsim.build_env(pinned, Scenario("x", (
            TargetedDelay(delay_ms=1e6, targets="minority"),)))
    big = netsim.build_env(CFG, Scenario("x", (
        TargetedDelay(delay_ms=1e6, targets="minority"),)))
    assert big is not None


def test_as_scenario_normalizes():
    assert as_scenario(None).events == ()
    s = Scenario("s")
    assert as_scenario(s) is s
    with pytest.raises(TypeError):
        as_scenario(42)


def test_library_compiles_and_stacks():
    lib = library.scenarios(CFG.sim_seconds, N)
    assert set(library.NAMES) == set(lib)
    pad = max(netsim.env_windows(CFG, s) for s in lib.values())
    envs = [netsim.build_env(CFG, s, pad) for s in lib.values()]
    stacked = netsim.stack_envs(envs)
    assert stacked["drop_tab"].shape == (len(lib), pad, N, N)
    with pytest.raises(KeyError, match="unknown scenario"):
        library.get("fig66", 2.0)


# ------------------------------------------------- batched sweep + trace ----

def test_scenario_grid_is_one_compiled_program():
    """>=3 scenarios x >=2 rates through run_sweep: at most one trace
    (zero when an earlier test already compiled the shared canonical
    program — the 32-row window floor makes this grid's signature common
    property), one distinct signature, and each point matches its single
    run_sim bitwise."""
    cfg = SMRConfig(sim_seconds=1.0)
    lib = library.scenarios(cfg.sim_seconds, N)
    scens = (lib["baseline"], lib["symmetric-partition"], lib["gray-wan"])
    spec = SweepSpec(rates=(10_000, 30_000), scenarios=scens)
    experiment.reset_trace_counts()
    grid = run_sweep("mandator-sporades", cfg, spec)
    assert experiment.trace_counts().get("mandator-sporades", 0) <= 1, \
        "a scenario grid must compile as ONE program"
    assert len(experiment.program_signatures()["mandator-sporades"]) == 1
    assert len(grid) == 6
    for r, (rate, seed, fi, _) in zip(grid, spec.points()):
        single = run_sim("mandator-sporades", cfg, rate_tx_s=rate,
                         scenario=scens[fi], seed=seed)
        for k in ("throughput", "median_ms", "p99_ms", "committed"):
            assert r[k] == single[k] or (np.isnan(r[k])
                                         and np.isnan(single[k]))
        np.testing.assert_array_equal(r["timeline"], single["timeline"])


# ------------------------------------------------------ partition physics ----

def _cvc_sum(cvc_all: np.ndarray, replica: int, t: int) -> int:
    return int(cvc_all[t, replica].sum())


def test_partition_blocks_minority_then_heals():
    """A partitioned minority stops committing once in-flight messages
    drain; after the heal it catches back up. The majority side (which
    keeps the view-0 leader) never stops."""
    cfg = SMRConfig(sim_seconds=3.0)
    minority, majority = (1, 2), (0, 3, 4)
    cut = Partition(1.0, 2.0, (minority, majority))
    healed = run_sim("mandator-sporades", cfg, rate_tx_s=20_000,
                     scenario=Scenario("heal", (cut,)))
    cvc = np.asarray(healed["cvc_all"])
    # in-flight drain margin: one max-RTT after the cut (~163 tick link)
    stall0 = _cvc_sum(cvc, 1, 1500)
    assert _cvc_sum(cvc, 1, 1999) == stall0, \
        "cut minority kept committing"
    assert _cvc_sum(cvc, 4, 1999) > _cvc_sum(cvc, 4, 1400), \
        "majority stalled during the partition"
    assert _cvc_sum(cvc, 1, 2999) > stall0, \
        "minority did not recover after heal"
    # and the healed run keeps end-to-end throughput
    assert np.asarray(healed["timeline"])[-1] > 0

    forever = run_sim("mandator-sporades", cfg, rate_tx_s=20_000,
                      scenario=Scenario("cut", (
                          Partition(1.0, math.inf, (minority, majority)),)))
    cvc2 = np.asarray(forever["cvc_all"])
    assert _cvc_sum(cvc2, 1, 2999) == _cvc_sum(cvc2, 1, 1500), \
        "permanently cut minority still advanced"
    assert _cvc_sum(cvc2, 4, 2999) > _cvc_sum(cvc2, 4, 1500), \
        "majority should out-run the permanent cut"
