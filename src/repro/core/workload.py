"""Open-loop Poisson workload (§5.2) + batch bookkeeping.

Batch records are global arrays indexed [origin, round]:
  create_t   — tick when the batch was formed
  arr_mean   — mean arrival tick of its requests (for execution latency)
  count      — number of requests in the batch
Commit times are reconstructed post-hoc from the per-tick committed-VC
trace (searchsorted), so the hot loop never touches [n, R_MAX] arrays.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.smr import SMRConfig


def init_workload(cfg: SMRConfig, n_ticks: int) -> Dict[str, jax.Array]:
    n = cfg.n_replicas
    return {
        "buffer": jnp.zeros((n,), jnp.float32),        # pending request count
        "buffer_tsum": jnp.zeros((n,), jnp.float32),   # sum of arrival ticks
        "last_batch_t": jnp.zeros((n,), jnp.float32),
        "cpu_tokens": jnp.zeros((n,), jnp.float32),
        "batch_create_t": jnp.full((n, n_ticks), jnp.inf, jnp.float32),
        "batch_arr_mean": jnp.zeros((n, n_ticks), jnp.float32),
        "batch_count": jnp.zeros((n, n_ticks), jnp.float32),
    }


def arrive(wl: Dict, key: jax.Array, t: jax.Array, rate_per_tick: jax.Array,
           alive: jax.Array) -> Dict:
    """Poisson arrivals this tick at each replica's colocated clients."""
    lam = jnp.broadcast_to(rate_per_tick, alive.shape)
    cnt = jax.random.poisson(key, lam).astype(jnp.float32) * alive
    wl = dict(wl)
    wl["buffer"] = wl["buffer"] + cnt
    wl["buffer_tsum"] = wl["buffer_tsum"] + cnt * t
    return wl


def refill_cpu(wl: Dict, cpu_req_per_tick: jax.Array) -> Dict:
    wl = dict(wl)
    wl["cpu_tokens"] = jnp.minimum(wl["cpu_tokens"] + cpu_req_per_tick, 1e7)
    return wl


def form_batches(wl: Dict, t: jax.Array, can_form: jax.Array,
                 round_idx: jax.Array, batch_size: int, batch_ticks: float
                 ) -> Tuple[Dict, jax.Array, jax.Array]:
    """can_form: [n] bool (protocol gate, e.g. ~awaitingAcks & alive).
    round_idx: [n] int32 — the chain round the new batch would get.
    Returns (wl, formed [n] bool, count [n] float)."""
    wl = dict(wl)
    size_ok = wl["buffer"] >= batch_size
    time_ok = (t - wl["last_batch_t"] >= batch_ticks) & (wl["buffer"] > 0)
    formed = can_form & (size_ok | time_ok) & (wl["cpu_tokens"] >= 1.0)
    count = jnp.where(formed,
                      jnp.minimum(jnp.minimum(wl["buffer"], batch_size),
                                  wl["cpu_tokens"]), 0.0)
    frac = jnp.where(wl["buffer"] > 0, count / jnp.maximum(wl["buffer"], 1.0), 0.0)
    tsum_taken = wl["buffer_tsum"] * frac
    arr_mean = jnp.where(count > 0, tsum_taken / jnp.maximum(count, 1.0), 0.0)
    n = count.shape[0]
    rows = jnp.arange(n)
    idx = jnp.clip(round_idx, 0, wl["batch_create_t"].shape[1] - 1)
    wl["batch_create_t"] = wl["batch_create_t"].at[rows, idx].min(
        jnp.where(formed, t.astype(jnp.float32), jnp.inf))
    wl["batch_arr_mean"] = wl["batch_arr_mean"].at[rows, idx].add(
        jnp.where(formed, arr_mean, 0.0))
    wl["batch_count"] = wl["batch_count"].at[rows, idx].add(count)
    wl["buffer"] = wl["buffer"] - count
    wl["buffer_tsum"] = wl["buffer_tsum"] - tsum_taken
    wl["cpu_tokens"] = wl["cpu_tokens"] - count
    wl["last_batch_t"] = jnp.where(formed, t.astype(jnp.float32),
                                   wl["last_batch_t"])
    return wl, formed, count
