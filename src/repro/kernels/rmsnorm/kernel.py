"""Fused (residual +) RMSNorm Pallas-TPU kernel.

Bandwidth-bound: one HBM read of x (+residual), one write. Grid tiles rows;
each block is [bn, D] in VMEM; statistics in fp32 VREGs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def _rmsnorm_res_kernel(x_ref, r_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def rmsnorm_2d(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
               residual: jax.Array | None = None, bn: int = 256,
               interpret: bool = False) -> jax.Array:
    """x: [N, D]; w: [D]."""
    n, d = x.shape
    bn = min(bn, n)
    assert n % bn == 0, (n, bn)
    row_spec = pl.BlockSpec((bn, d), lambda i: (i, 0))
    w_spec = pl.BlockSpec((d,), lambda i: (0,))
    if residual is None:
        kernel = functools.partial(_rmsnorm_kernel, eps=eps)
        in_specs = [row_spec, w_spec]
        args = (x, w)
    else:
        kernel = functools.partial(_rmsnorm_res_kernel, eps=eps)
        in_specs = [row_spec, row_spec, w_spec]
        args = (x, residual, w)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=in_specs,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(*args)
