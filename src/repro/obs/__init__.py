"""Protocol flight recorder + health monitor (observability substrate).

Five layers, consensus-agnostic:

  - ``obs.trace``   — on-device event rings + counters, vmap-safe, carried
    inside the protocol scan; statically gated by ``SMRConfig.trace_level``
    so ``off`` (the default) compiles to the identical program;
  - ``obs.monitor`` — on-device safety/liveness invariant checks + resource
    gauges, same carry, same static gating via ``SMRConfig.monitor_level``;
  - ``obs.decode``  — host-side ring -> per-replica event timelines;
  - ``obs.export``  — Chrome/Perfetto ``trace_event`` JSON (phase spans,
    event instants, throughput + gauge counter tracks) + the per-phase
    latency table (``benchmarks/inspect.py`` and the demo's ``--trace``
    drive both);
  - ``obs.history`` — the append-only ``BENCH_history.jsonl`` benchmark
    ledger and the CI regression gate (``compare``).

See docs/ARCHITECTURE.md "Observability".
"""
from repro.obs import decode, export, history, monitor  # noqa: F401
from repro.obs.monitor import (  # noqa: F401
    MONITOR_ENV, VIOLATIONS, HostMonitor, MonitorLevel,
)
from repro.obs.trace import (  # noqa: F401
    DEFAULT_SPEC, FIELDS, PHASES, TRACE_ENV, HostTrace, TraceLevel,
    TraceSpec, init_trace, level_from_env, public_view, record, record_env,
)
