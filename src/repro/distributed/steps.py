"""jit-able train / prefill / decode steps with sharding constraints.

train_step: loss -> grad -> AdamW update (optionally int8 moments, int8
error-feedback gradient compression across the DP axes).
serve_step: one decode token against a (possibly sequence-sharded) cache.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import CallConfig, forward_decode, init_cache, loss_fn
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state


def make_train_step(cfg: ModelConfig, call: CallConfig, opt: AdamWConfig):
    def train_step(params, opt_state, batch):
        def lf(p):
            return loss_fn(p, cfg, call, batch)

        (loss, parts), grads = jax.value_and_grad(lf, has_aux=True)(params)
        params, opt_state, om = apply_updates(opt, params, grads, opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, call: CallConfig):
    def serve_step(params, cache, batch, pos):
        logits, cache = forward_decode(params, cfg, call, batch, cache, pos)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return token, cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, call: CallConfig):
    from repro.models import forward_train

    def prefill_step(params, batch):
        logits, _ = forward_train(params, cfg, call, batch)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    return prefill_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation) — dry-run contract
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Stand-ins for every model input of the given workload shape."""
    b = shape.global_batch
    s = shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch: Dict[str, Any] = {}
        if cfg.embed_inputs:
            batch["tokens"] = sds((b, s), jnp.int32)
        else:
            batch["frame_emb"] = sds((b, s, cfg.d_model), dtype)
        batch["labels"] = sds((b, s), jnp.int32)
        if cfg.cross_attn is not None:
            batch["vision_mem"] = sds((b, cfg.cross_attn.n_mem_tokens,
                                       cfg.d_model), dtype)
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.embed_inputs:
            batch["tokens"] = sds((b, s), jnp.int32)
        else:
            batch["frame_emb"] = sds((b, s, cfg.d_model), dtype)
        if cfg.cross_attn is not None:
            batch["vision_mem"] = sds((b, cfg.cross_attn.n_mem_tokens,
                                       cfg.d_model), dtype)
        return batch
    # decode: one new token against a cache of length seq_len
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = sds((b,), jnp.int32)
    else:
        batch["frame_emb"] = sds((b, 1, cfg.d_model), dtype)
    if cfg.cross_attn is not None:
        batch["vision_mem"] = sds((b, cfg.cross_attn.n_mem_tokens,
                                   cfg.d_model), dtype)
    return batch


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        partial(init_cache, cfg, shape.global_batch, shape.seq_len, dtype))
