"""The paper's §5 in one script: run Mandator-Sporades and the baselines on
the simulated 5-region WAN; reproduce the Fig. 6 ordering and the Fig. 7
leader-crash recovery.

Sweeps go through the batched experiment engine: each protocol's rate grid
is one compiled vmapped program (see docs/ARCHITECTURE.md).

  PYTHONPATH=src python examples/wan_consensus_demo.py

Scenario showcase — run any adversary from the curated library
(scenarios/library.py) and watch the throughput timeline around its
windows:

  PYTHONPATH=src python examples/wan_consensus_demo.py --scenario region-outage

Workload showcase — run any traffic shape from the curated workload
library (workloads/library.py) and watch where the latency is paid,
region by region; composes with --scenario:

  PYTHONPATH=src python examples/wan_consensus_demo.py --workload region-skew
  PYTHONPATH=src python examples/wan_consensus_demo.py \\
      --workload closed-loop --scenario paper-ddos

Flight recorder — rerun any of the above with ``--trace out.json`` to get
the per-phase latency breakdown (queue / dissemination / consensus /
delivery) on stdout plus a Chrome/Perfetto trace of the
Mandator-Sporades point, loadable at ui.perfetto.dev:

  PYTHONPATH=src python examples/wan_consensus_demo.py \\
      --trace ddos.json --scenario paper-ddos --rate 300000
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs.smr import REGIONS, SMRConfig
from repro.core import compile_cache
from repro.core.experiment import SweepSpec, run_sweep
from repro.scenarios import Crash, Scenario
from repro.scenarios import library
from repro.workloads import library as workload_library


def paper_tour() -> None:
    cfg = SMRConfig(sim_seconds=3.0)
    print("== best-case WAN (5 regions: Virginia, Ireland, Mumbai, "
          "São Paulo, Tokyo) ==")
    for proto, rate in (("mandator-sporades", 400_000),
                        ("mandator-paxos", 400_000),
                        ("multipaxos", 100_000),
                        ("epaxos", 10_000),
                        ("rabia", 1_000)):
        r = run_sweep(proto, cfg, SweepSpec(rates=(rate,)))[0]
        print(f" {proto:20s} saturation ~{r['throughput']:8.0f} tx/s "
              f"@ {r['median_ms']:6.0f} ms median")

    print("\n== leader crash at t=1.5s (Fig. 7) ==")
    spec = SweepSpec(rates=(100_000,),
                     scenarios=(Scenario("leader-crash",
                                      (Crash(start_s=1.5, targets=(0,)),)),))
    for proto in ("mandator-sporades", "mandator-paxos"):
        r = run_sweep(proto, cfg, spec)[0]
        tl = "|".join(f"{x/1000:.0f}k" for x in r["timeline"])
        print(f" {proto:20s} [{tl}] tx/s per 500ms")


def scenario_showcase(name: str, sim_s: float, rate: float) -> None:
    cfg = SMRConfig(sim_seconds=sim_s)
    scen = library.get(name, sim_s, cfg.n_replicas)
    windows = [(getattr(ev, "start_s", getattr(ev, "at_s", 0.0)),
                getattr(ev, "end_s", float("inf")), type(ev).__name__)
               for ev in scen.events]
    print(f"== scenario {name!r} on the 5-region WAN "
          f"({sim_s:.0f}s sim, {rate:,.0f} tx/s offered) ==")
    for s, e, kind in windows:
        end = f"{min(e, sim_s):.2f}s" if e != float("inf") else "end"
        print(f"  {kind:17s} {s:.2f}s -> {end}")
    spec = SweepSpec(rates=(rate,), scenarios=(scen,))
    for proto in ("mandator-sporades", "mandator-paxos", "multipaxos"):
        r = run_sweep(proto, cfg, spec)[0]
        print(f"\n {proto}: {r['throughput']:,.0f} tx/s overall, "
              f"median {r['median_ms']:.0f} ms")
        tl = np.asarray(r["timeline"])
        bucket_s = sim_s / len(tl)
        marks = "".join(
            "#" if any(s <= (b + 0.5) * bucket_s < min(e, sim_s)
                       for s, e, _ in windows) else "."
            for b in range(len(tl)))
        print(f"   window  [{marks}]  (# = adversity active)")
        print("   tx/s    [" + "|".join(f"{x/1000:.0f}k" for x in tl) + "]"
              f"  per {bucket_s * 1000:.0f}ms bucket")


def workload_showcase(wname: str, sname: str, sim_s: float,
                      rate: float) -> None:
    """Per-region view of a traffic shape (optionally under an adversary):
    who commits how much, and where the latency is paid."""
    cfg = SMRConfig(sim_seconds=sim_s)
    n = cfg.n_replicas
    wl = workload_library.get(wname, sim_s, n)
    scen = library.get(sname, sim_s, n) if sname else None
    closed = any(type(s).__name__ == "ClosedLoop" for s in wl.shapes)
    print(f"== workload {wname!r}"
          + (f" under scenario {sname!r}" if sname else "")
          + f" ({sim_s:.0f}s sim, {rate:,.0f} tx/s "
          + ("client-pool target" if closed else "offered") + ") ==")
    spec = SweepSpec(rates=(rate,), scenarios=(scen,), workloads=(wl,))
    for proto in ("mandator-sporades", "mandator-paxos"):
        r = run_sweep(proto, cfg, spec)[0]
        print(f"\n {proto}: {r['throughput']:,.0f} tx/s overall, "
              f"median {r['median_ms']:.0f} ms, p99 {r['p99_ms']:.0f} ms")
        lat_tl = np.asarray(r["origin_lat_ms_timeline"])   # [n, buckets]
        tl = np.asarray(r["origin_timeline"])
        bucket_s = sim_s / lat_tl.shape[1]
        med = np.asarray(r["origin_median_ms"])
        p99 = np.asarray(r["origin_p99_ms"])
        infl = r.get("inflight_max")
        for i in range(n):
            cells = "|".join("   ." if not np.isfinite(x) else f"{x:4.0f}"
                             for x in lat_tl[i])
            extra = f"  max in-flight {infl[i]:5.0f}" if infl is not None \
                else ""
            print(f"   {REGIONS[i][:8]:8s} med {med[i]:6.0f} ms  "
                  f"p99 {p99[i]:6.0f} ms  share {tl[i].sum() / max(tl.sum(), 1e-9):5.1%}{extra}")
            print(f"            lat/ms  [{cells}]  per "
                  f"{bucket_s * 1000:.0f}ms bucket")


def traced_run(trace_path: str, sname: str, wname: str, sim_s: float,
               rate: float) -> None:
    """Flight-recorder view of one point (composes with --scenario /
    --workload): per-phase latency tables for the Mandator protocols plus
    a Perfetto trace of the Mandator-Sporades run."""
    from repro.obs import export

    cfg = SMRConfig(sim_seconds=sim_s, trace_level="full")
    scen = library.get(sname, sim_s, cfg.n_replicas) if sname else None
    wl = workload_library.get(wname, sim_s, cfg.n_replicas) if wname else None
    print(f"== flight recorder @ {rate:,.0f} tx/s"
          + (f", scenario {sname!r}" if sname else "")
          + (f", workload {wname!r}" if wname else "")
          + f" ({sim_s:.0f}s sim) ==")
    spec = SweepSpec(rates=(rate,), scenarios=(scen,), workloads=(wl,))
    for proto in ("mandator-sporades", "mandator-paxos"):
        r = run_sweep(proto, cfg, spec)[0]
        print(f"\n {proto}: {r['throughput']:,.0f} tx/s, "
              f"median {r['median_ms']:.0f} ms")
        print(export.phase_table(r))
        if proto == "mandator-sporades":
            p = export.write(trace_path,
                             export.chrome_trace(r, cfg, proto,
                                                 scenario=scen))
            print(f"\n# wrote {p} — open at https://ui.perfetto.dev")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="",
                    help=f"showcase one of: {', '.join(library.NAMES)}")
    ap.add_argument("--workload", default="",
                    help="per-region latency view of one of: "
                         f"{', '.join(workload_library.NAMES)} "
                         "(composes with --scenario)")
    ap.add_argument("--sim-seconds", type=float, default=4.0)
    ap.add_argument("--rate", type=float, default=100_000)
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="run the flight recorder: write a Chrome/Perfetto "
                         "trace of the (--scenario/--workload-composed) "
                         "point here and print the per-phase latency table")
    ap.add_argument("--no-compile-cache", action="store_true",
                    help="disable the persistent XLA compile cache "
                         "(the first demo run seeds it; repeat runs then "
                         "skip XLA compilation entirely)")
    args = ap.parse_args()
    if args.no_compile_cache:
        compile_cache.disable()
    else:
        print(f"# persistent compile cache: {compile_cache.enable()}",
              file=sys.stderr)
    if args.trace:
        traced_run(args.trace, args.scenario, args.workload,
                   args.sim_seconds, args.rate)
    elif args.workload:
        workload_showcase(args.workload, args.scenario, args.sim_seconds,
                          args.rate)
    elif args.scenario:
        scenario_showcase(args.scenario, args.sim_seconds, args.rate)
    else:
        paper_tour()


if __name__ == "__main__":
    main()
