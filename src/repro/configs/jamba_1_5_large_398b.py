"""jamba-1.5-large-398b — Mamba+attn 1:7 interleave, MoE 16e top-2.  [arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    attn_every=8,   # 1 attention layer per 8 (9 of 72), rest Mamba
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, every=2),
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2, chunk=128),
    notes="hybrid SSM/attention with MoE every other layer",
)
