"""Common-coin-flip(v) — the paper's §3.2.1 primitive, Rabia-style.

Every replica holds the same (shared-secret) seed; common_coin_flip(v)
derives the view-v leader with a PRNG keyed by (seed, v). Properties
(§3.2.1): (1) same output at every replica for the same v; (2) independent
across views. Implemented with jax.random so the training runtime
(runtime/sporades_rt.py) and the WAN sim share the exact primitive.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def common_coin_flip(v: jax.Array | int, n: int, seed: int = 0) -> jax.Array:
    """Deterministic uniform int in [0, n) for view v."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), jnp.asarray(v, jnp.uint32))
    return jax.random.randint(key, (), 0, n)


def coin_table(max_views: int, n: int, seed: int = 0) -> jax.Array:
    """Pre-generated coins for views [0, max_views) — the paper's
    'pre-generate random numbers for each view number' implementation."""
    keys = jax.vmap(lambda v: jax.random.fold_in(jax.random.PRNGKey(seed), v))(
        jnp.arange(max_views, dtype=jnp.uint32))
    return jax.vmap(lambda k: jax.random.randint(k, (), 0, n))(keys)
