"""Flash-decoding Pallas-TPU kernel: single-token query, long KV cache.

The decode roofline cells are HBM-bound on the cache read (EXPERIMENTS.md
§Roofline); this kernel streams KV blocks HBM->VMEM once with a running
(m, l, acc) online softmax — the decode analogue of flash attention, and
the structure that a sequence-sharded cache composes with (each shard
reduces its local blocks; the tiny (acc, m, l) combine crosses shards).

Grid: (batch, q_head, S/bs); the last dim is sequential so fp32 scratch
persists. GQA via kv-head index map h // (H/Kh). ``kv_len`` masks the
unfilled cache tail (delivered via a [B, 1] int32 operand).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, bs: int, num_s: int):
    is_ = pl.program_id(2)

    @pl.when(is_ == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                # [1, d] (lane-major)
    k = k_ref[0, 0].astype(jnp.float32)                # [bs, d]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [1, bs]
    s = s * (1.0 / (q.shape[-1] ** 0.5))
    pos = is_ * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(pos < len_ref[0, 0], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(is_ == num_s - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            kv_len: jax.Array, *, bs: int = 512,
                            interpret: bool = False) -> jax.Array:
    """q: [B, H, D]; k, v: [B, Kh, S, D]; kv_len: [B] int32 -> [B, H, D]."""
    b, h, d = q.shape
    kh, s = k.shape[1], k.shape[2]
    assert h % kh == 0 and s % bs == 0, (q.shape, k.shape, bs)
    group = h // kh
    num_s = s // bs
    kernel = functools.partial(_decode_kernel, bs=bs, num_s=num_s)
    return pl.pallas_call(
        kernel,
        grid=(b, h, num_s),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ib, ih, is_: (ib, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, d), lambda ib, ih, is_: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda ib, ih, is_: (ib, ih // group, is_, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda ib, ih, is_: (ib, ih // group, is_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda ib, ih, is_: (ib, ih, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.reshape(b, 1).astype(jnp.int32),
      q.reshape(b, h, 1, d), k, v).reshape(b, h, d)
