"""R5 carry-hygiene: level-gated subtree stored without a guard."""


def make_state(level, base):
    tr = init_trace(level)  # noqa: F821 — parsed, never imported
    return {"base": base, "tr": tr}  # expect: R5
