"""Chrome/Perfetto ``trace_event`` export of one traced sweep point.

``chrome_trace`` turns a FULL-level result dict (harness.sim_point via the
experiment engine) into the JSON Object Format that ui.perfetto.dev and
chrome://tracing load directly:

  - one *process* (pid) per replica, named after its region;
  - per replica, one *thread* (tid) per view: the batch-phase track
    (``X`` duration events for dissemination / consensus / delivery of
    every committed batch), the protocol-mode track (``X`` spans covering
    async-mode intervals), and one instant-event (``i``) track per
    protocol layer straight from the decoded flight-recorder ring;
  - a cluster-level process carrying the scenario windows (``X`` spans +
    ``i`` instants) and the committed-throughput counter track (``C``).

Timestamps are microseconds (trace_event's native unit) derived from
simulator ticks via ``cfg.tick_ms``.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from repro.obs import decode as _decode
from repro.obs.trace import DEFAULT_SPEC, PHASES, TraceSpec

# thread ids inside each replica process
TID_PHASES = 0
TID_MODE = 1
_LAYER_TID0 = 2      # layer instant tracks start here, in sorted order

# cluster-process thread ids: 0 = scenario, 1 = committed tx/s counter,
# then the health-monitor gauge counters (repro.obs.monitor)
TID_GAUGE_OCC = 2
TID_GAUGE_DROP = 3

_PH_ALLOWED = {"M", "i", "I", "X", "C"}

# batch_marks_t rows (harness.sim_point): absolute ticks of each boundary
MARKS = ("create", "stable", "commit", "deliver")


def _us(ticks, tick_ms: float) -> float:
    return float(ticks) * tick_ms * 1000.0


def _meta(pid: int, name: str, tid: Optional[int] = None,
          tname: Optional[str] = None) -> List[Dict]:
    ev = [{"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
           "args": {"name": name}}]
    if tid is not None:
        ev = [{"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
               "args": {"name": tname}}]
    return ev


def chrome_trace(result: Dict, cfg, protocol: str, scenario=None,
                 regions: Optional[List[str]] = None,
                 spec: TraceSpec = DEFAULT_SPEC,
                 max_batches: int = 4096) -> Dict:
    """Build the trace_event JSON dict for one FULL-level sweep point.
    ``scenario`` (a repro.scenarios.Scenario or None) contributes the
    adversity windows; ``max_batches`` bounds the per-origin batch-span
    count (newest kept) so hot sweeps stay loadable."""
    if "obs" not in result:
        raise ValueError(
            "result has no flight-recorder data; run with "
            "SMRConfig(trace_level='full')")
    if regions is None:
        from repro.configs.smr import REGIONS
        regions = list(REGIONS)
    decoded = _decode.decode_result(result, spec)
    layers = sorted(decoded)
    tick_ms = cfg.tick_ms
    n = np.asarray(result["obs"][layers[0]]["counts"]).shape[0]
    ev: List[Dict] = []

    for i in range(n):
        name = regions[i] if i < len(regions) else f"replica-{i}"
        ev += _meta(i, f"replica {i} ({name})")
        ev += _meta(i, "", TID_PHASES, "batch phases")
        ev += _meta(i, "", TID_MODE, f"{protocol} mode")
        for li, layer in enumerate(layers):
            ev += _meta(i, "", _LAYER_TID0 + li, f"{layer} events")

    # ---- batch phase spans (X) from the commit-boundary marks ----------
    marks = result.get("batch_marks_t")
    if marks is not None:
        marks = np.asarray(marks)                       # [4, n, R]
        count = np.asarray(result.get("batch_n"))       # [n, R]
        spans = (("dissemination", 0, 1), ("consensus", 1, 2),
                 ("delivery", 2, 3))
        for i in range(n):
            ok = np.isfinite(marks[:, i, :]).all(axis=0) & (count[i] > 0)
            rounds = np.nonzero(ok)[0][-max_batches:]
            for r in rounds:
                for pname, j0, j1 in spans:
                    t0, t1 = marks[j0, i, r], marks[j1, i, r]
                    ev.append({
                        "ph": "X", "pid": i, "tid": TID_PHASES,
                        "name": pname, "cat": "batch",
                        "ts": _us(t0, tick_ms),
                        "dur": max(_us(t1 - t0, tick_ms), 0.0),
                        "args": {"round": int(r),
                                 "requests": int(count[i, r])}})

    # ---- per-layer instant events + async-mode spans from the rings ----
    # timeline buckets are 500ms (harness._batch_metrics) -> sim length
    sim_us = (np.asarray(result["timeline"]).shape[0] * 500e3
              if "timeline" in result else None)
    for li, layer in enumerate(layers):
        for i, rep in enumerate(decoded[layer]):
            open_async: Optional[float] = None
            for e in rep.get("events", ()):
                ts = _us(e["tick"], tick_ms)
                ev.append({"ph": "i", "pid": i, "tid": _LAYER_TID0 + li,
                           "name": e["name"], "cat": layer, "ts": ts,
                           "s": "t", "args": dict(e["args"])})
                if e["name"] == "mode_switch":
                    if e["args"].get("is_async"):
                        open_async = ts
                    elif open_async is not None:
                        ev.append({"ph": "X", "pid": i, "tid": TID_MODE,
                                   "name": "async mode", "cat": layer,
                                   "ts": open_async,
                                   "dur": max(ts - open_async, 0.0),
                                   "args": {}})
                        open_async = None
            if open_async is not None and sim_us is not None:
                ev.append({"ph": "X", "pid": i, "tid": TID_MODE,
                           "name": "async mode", "cat": layer,
                           "ts": open_async,
                           "dur": max(sim_us - open_async, 0.0),
                           "args": {}})

    # ---- cluster process: scenario windows + throughput counter --------
    pid_c = n
    ev += _meta(pid_c, "cluster")
    ev += _meta(pid_c, "", 0, "scenario")
    ev += _meta(pid_c, "", 1, "committed tx/s")
    if scenario is not None:
        for s in getattr(scenario, "events", ()):
            start = getattr(s, "start_s", getattr(s, "at_s", 0.0))
            end = getattr(s, "end_s", float("inf"))
            ts = start * 1e6
            kind = type(s).__name__
            ev.append({"ph": "i", "pid": pid_c, "tid": 0, "name": kind,
                       "cat": "scenario", "ts": ts, "s": "p",
                       "args": {"start_s": start}})
            if np.isfinite(end):
                ev.append({"ph": "X", "pid": pid_c, "tid": 0, "name": kind,
                           "cat": "scenario", "ts": ts,
                           "dur": max((end - start) * 1e6, 0.0), "args": {}})
    if "timeline" in result:
        tl = np.asarray(result["timeline"])
        for b, v in enumerate(tl):
            ev.append({"ph": "C", "pid": pid_c, "tid": 1,
                       "name": "committed tx/s", "ts": b * 500e3,
                       "args": {"tx_s": float(v)}})

    # ---- health-monitor resource gauges as counter tracks --------------
    # (repro.obs.monitor; present when the point ran with monitor_level
    # != "off" — same 500ms buckets as the throughput counter)
    mon = result.get("mon")
    if mon is not None:
        ev += _meta(pid_c, "", TID_GAUGE_OCC, "ring occupancy")
        ev += _meta(pid_c, "", TID_GAUGE_DROP, "dropped sends/s")
        occ = np.asarray(mon["occ_tl"])
        drp = np.asarray(mon["drop_tl"])
        for b in range(occ.shape[0]):
            ev.append({"ph": "C", "pid": pid_c, "tid": TID_GAUGE_OCC,
                       "name": "ring occupancy", "ts": b * 500e3,
                       "args": {"occupancy": float(occ[b])}})
            ev.append({"ph": "C", "pid": pid_c, "tid": TID_GAUGE_DROP,
                       "name": "dropped sends/s", "ts": b * 500e3,
                       "args": {"sends_s": float(drp[b]) / 0.5}})

    return {"displayTimeUnit": "ms", "traceEvents": ev,
            "otherData": {"protocol": protocol,
                          "scenario": getattr(scenario, "name", "baseline"),
                          "tick_ms": tick_ms}}


def validate(trace: Dict) -> None:
    """Structural trace_event-schema check (what chrome://tracing and
    Perfetto require to load): raises ValueError on the first violation."""
    if trace.get("displayTimeUnit") not in ("ms", "ns"):
        raise ValueError("missing/invalid displayTimeUnit")
    evs = trace.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        raise ValueError("traceEvents must be a non-empty list")
    for k, e in enumerate(evs):
        ph = e.get("ph")
        if ph not in _PH_ALLOWED:
            raise ValueError(f"event {k}: unsupported ph {ph!r}")
        for f in ("pid", "tid"):
            if not isinstance(e.get(f), int):
                raise ValueError(f"event {k}: {f} must be an int")
        if not isinstance(e.get("name"), str):
            raise ValueError(f"event {k}: missing name")
        if ph != "M":
            if not isinstance(e.get("ts"), (int, float)):
                raise ValueError(f"event {k}: missing ts")
            if e["ts"] < 0:
                raise ValueError(f"event {k}: negative ts")
        if ph == "X":
            if not isinstance(e.get("dur"), (int, float)) or e["dur"] < 0:
                raise ValueError(f"event {k}: X event needs dur >= 0")
        if ph == "C":
            # counter tracks must carry at least one finite numeric series
            # value (Perfetto drops NaN/non-numeric counter samples)
            a = e.get("args")
            if not isinstance(a, dict) or not a:
                raise ValueError(f"event {k}: C event needs args")
            for ak, av in a.items():
                if not isinstance(av, (int, float)) or not np.isfinite(av):
                    raise ValueError(
                        f"event {k}: C arg {ak!r} must be finite numeric")


def write(path, trace: Dict) -> Path:
    """Validate + write the trace JSON; returns the path."""
    validate(trace)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(trace))
    return p


def phases_dict(result: Dict) -> Optional[Dict]:
    """The phase-breakdown quantiles of one result as a JSON-able dict:
    {phase: {"med_ms", "p99_ms"}} (None when the point was untraced)."""
    if "phase_med_ms" not in result:
        return None
    med = np.asarray(result["phase_med_ms"])
    p99 = np.asarray(result["phase_p99_ms"])
    fin = lambda x: float(x) if np.isfinite(x) else None  # noqa: E731
    return {ph: {"med_ms": fin(med[j]), "p99_ms": fin(p99[j])}
            for j, ph in enumerate(PHASES)}


def phase_table(result: Dict, regions: Optional[List[str]] = None) -> str:
    """Human-readable per-phase latency breakdown of one traced point:
    the cluster-wide quantiles plus the per-origin medians."""
    if "phase_med_ms" not in result:
        return "(no phase breakdown: run with trace_level != 'off')"
    med = np.asarray(result["phase_med_ms"])
    p99 = np.asarray(result["phase_p99_ms"])
    omed = np.asarray(result["phase_origin_med_ms"])    # [4, n]
    if regions is None:
        from repro.configs.smr import REGIONS
        regions = list(REGIONS)
    fmt = lambda x: f"{x:8.1f}" if np.isfinite(x) else "       -"  # noqa
    lines = [f" {'phase':16s} {'median':>8s} {'p99':>8s}   (ms)"]
    for j, ph in enumerate(PHASES):
        lines.append(f" {ph:16s} {fmt(med[j])} {fmt(p99[j])}")
    e2e_med, e2e_p99 = result.get("median_ms"), result.get("p99_ms")
    if e2e_med is not None:
        lines.append(f" {'end-to-end':16s} {fmt(e2e_med)} {fmt(e2e_p99)}")
    n = omed.shape[1]
    hdr = " ".join(f"{ph[:7]:>8s}" for ph in PHASES)
    lines.append(f"\n per-origin medians (ms):\n {'origin':10s} {hdr}")
    for i in range(n):
        name = regions[i] if i < len(regions) else f"r{i}"
        cells = " ".join(fmt(omed[j, i]) for j in range(len(PHASES)))
        lines.append(f" {name[:10]:10s} {cells}")
    return "\n".join(lines)
