"""Known-good idioms: every rule has something here it must NOT flag."""
import numpy as np


class Send:
    pass


def ring_commit(ring, sends, drop=None):
    return ring, sends, drop


# lint: traced-root
def tick(state):
    # lint: allow(traced-purity): static layout table folded at trace time
    lanes = np.arange(4)
    return state, lanes


def relay(ring, inbox, drop):
    msgs = [Send() for _ in inbox]
    return ring_commit(ring, msgs, drop=drop)


def make_state(level, base):
    tr = init_trace(level)  # noqa: F821 — parsed, never imported
    if tr is not None:
        return {"base": base, "tr": tr}
    return {"base": base}
