"""Grid-axis device mesh helpers for the mesh-sharded sweep engine.

The sweep engine (core/experiment.py) shards the flattened
workload x scenario x rate grid over a 1-D ``("grid",)`` mesh: point i
runs on device i % D, each device executing the same canonical
CANONICAL_LANES program over its slice, with metrics reduced on device.
This module owns Mesh construction so experiment code and benchmarks
share one layout definition.

CPU multi-device testing: set ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
in the environment BEFORE jax initializes its backend (e.g. via a
subprocess env or the CI job env) and ``jax.devices()`` reports 8 host
devices; ``grid_mesh()`` then builds an 8-way grid mesh.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh

GRID_AXIS = "grid"


def grid_mesh(devices: Union[None, int, Sequence[jax.Device]] = None) -> Mesh:
    """Build the 1-D ``("grid",)`` mesh.

    ``devices`` may be None (all local devices), an int (first N local
    devices), or an explicit device sequence.
    """
    if devices is None:
        devs = jax.devices()
    elif isinstance(devices, int):
        avail = jax.devices()
        if devices < 1 or devices > len(avail):
            raise ValueError(
                f"grid_mesh: asked for {devices} devices, have {len(avail)}")
        devs = avail[:devices]
    else:
        devs = list(devices)
    import numpy as np
    return Mesh(np.array(devs), (GRID_AXIS,))


def as_grid_mesh(mesh: Union[None, int, Mesh]) -> Optional[Mesh]:
    """Normalize a ``mesh=`` argument: None stays None (legacy dispatch),
    an int becomes an N-device grid mesh, a Mesh must expose the grid axis."""
    if mesh is None:
        return None
    if isinstance(mesh, Mesh):
        if GRID_AXIS not in mesh.axis_names:
            raise ValueError(
                f"mesh must have a {GRID_AXIS!r} axis, got {mesh.axis_names}")
        return mesh
    return grid_mesh(int(mesh))


def device_counts(max_devices: Optional[int] = None) -> Tuple[int, ...]:
    """Power-of-two device counts available for a scaling curve:
    (1, 2, 4, ..., D) up to the local device count (or ``max_devices``)."""
    limit = len(jax.devices())
    if max_devices is not None:
        limit = min(limit, max_devices)
    counts = []
    d = 1
    while d <= limit:
        counts.append(d)
        d *= 2
    if counts[-1] != limit:
        counts.append(limit)
    return tuple(counts)
