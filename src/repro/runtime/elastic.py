"""Elastic scaling + straggler mitigation for the pure-DP pod axis.

Because (a) the pod axis carries no model state (DESIGN.md §4) and (b) the
data pipeline is a pure function of (seed, step, shard, n_shards), scaling
from P to P' pods is a *deterministic replan*: survivors re-derive their
batch shards and the Sporades commit quorum shrinks/grows — no resharding
of weights across the pod axis is ever needed. Straggler mitigation commits
a step with the quorum's gradients, rescaled by the participation fraction
(bounded-staleness correction).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class ShardPlan:
    step: int
    n_pods: int
    pods: Tuple[int, ...]          # surviving pod ids, sorted
    shard_of: Dict[int, int]       # pod id -> data shard index

    @property
    def n_shards(self) -> int:
        return len(self.pods)


def replan(step: int, live_pods: List[int]) -> ShardPlan:
    pods = tuple(sorted(live_pods))
    return ShardPlan(step=step, n_pods=len(pods), pods=pods,
                     shard_of={p: i for i, p in enumerate(pods)})


def grad_scale(n_participating: int, n_planned: int) -> float:
    """Straggler drop: mean-of-means correction when only a quorum of pod
    gradients made the deadline (unbiased if shards are iid)."""
    assert 0 < n_participating <= n_planned
    return n_planned / n_participating


@dataclass
class StragglerPolicy:
    """Deadline policy: wait for all pods up to `deadline_ms`; after that
    commit with >= quorum gradients (Sporades async path decides whose)."""
    deadline_ms: float = 250.0
    min_quorum_frac: float = 0.5

    def decide(self, arrival_ms: Dict[int, float], n_pods: int
               ) -> Tuple[List[int], bool]:
        """Returns (participating pods, used_fallback)."""
        on_time = [p for p, t in arrival_ms.items() if t <= self.deadline_ms]
        if len(on_time) == n_pods:
            return sorted(on_time), False
        quorum = max(int(np.ceil(n_pods * self.min_quorum_frac)),
                     n_pods - (n_pods - 1) // 2)
        if len(on_time) >= quorum:
            return sorted(on_time), True
        # below quorum: wait for the stragglers (liveness over latency)
        return sorted(arrival_ms), True
