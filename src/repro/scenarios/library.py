"""Curated scenario library — the adversaries the robustness matrix runs.

Windows are placed at fractions of ``sim_s`` so the same shapes stress a
2-second smoke run and a 10-second sweep alike. ``scenarios(sim_s)``
returns an ordered name -> Scenario dict; ``get(name, sim_s)`` fetches one.
"""
from __future__ import annotations

import math
from typing import Dict

from repro.scenarios.primitives import (
    BandwidthThrottle,
    Crash,
    GrayFailure,
    Partition,
    RegionOutage,
    Scenario,
    TargetedDelay,
)


def _minority_split(n: int):
    f = (n - 1) // 2
    return tuple(range(f)), tuple(range(f, n))


def scenarios(sim_s: float, n: int = 5) -> Dict[str, Scenario]:
    minority, majority = _minority_split(n)
    flap_on = 0.12 * sim_s
    return {
        "baseline": Scenario("baseline"),
        # the paper's §5.5 attack: random minority re-picked every second
        "paper-ddos": Scenario("paper-ddos", (
            TargetedDelay(delay_ms=800.0, targets="random-minority",
                          repick_s=1.0, seed=7),)),
        # pin the attack on the initial leader instead of a rotating minority
        "leader-ddos": Scenario("leader-ddos", (
            TargetedDelay(delay_ms=800.0, targets="leader"),)),
        # clean two-sided cut mid-run, heals: minority side must stall,
        # then catch up
        "symmetric-partition": Scenario("symmetric-partition", (
            Partition(0.4 * sim_s, 0.7 * sim_s, (minority, majority)),)),
        # the minority is cut off for good — the majority side must keep
        # committing without it
        "minority-partition": Scenario("minority-partition", (
            Partition(0.4 * sim_s, math.inf, (minority, majority)),)),
        # a whole region goes dark and the surviving WAN reroutes
        "region-outage": Scenario("region-outage", (
            RegionOutage(0.4 * sim_s, 0.7 * sim_s, regions=(2,),
                         delay_ms=50.0),)),
        # sustained gray failure: per-link jitter + loss, re-drawn at 10 Hz
        "gray-wan": Scenario("gray-wan", (
            GrayFailure(0.2 * sim_s, 0.9 * sim_s, loss=0.05, jitter_ms=25.0,
                        redraw_s=0.1, seed=11),)),
        # one link flaps on/off four times
        "flapping-link": Scenario("flapping-link", tuple(
            Partition((0.2 + 0.2 * k) * sim_s,
                      (0.2 + 0.2 * k + flap_on) * sim_s, ((0,), (1,)))
            for k in range(4))),
        # the leader's NIC degrades to 10% mid-run
        "throttled-nic": Scenario("throttled-nic", (
            BandwidthThrottle(0.3 * sim_s, math.inf, scale=0.1,
                              targets="leader"),)),
        # crash as an *interval*: the leader is down for a third of the run
        # and comes back
        "leader-crash-recover": Scenario("leader-crash-recover", (
            Crash(0.3 * sim_s, targets="leader", end_s=0.6 * sim_s),)),
    }


NAMES = tuple(scenarios(1.0))


def get(name: str, sim_s: float, n: int = 5) -> Scenario:
    lib = scenarios(sim_s, n)
    if name not in lib:
        raise KeyError(
            f"unknown scenario {name!r}; known: {', '.join(lib)}")
    return lib[name]
