"""Benchmark run history: an append-only JSONL ledger + regression gate.

``benchmarks/run.py`` appends one entry per invocation to
``BENCH_history.jsonl`` at the repo root: git sha, wall-clock timestamp,
quick flag, and the per-suite timing/compile/cache stats plus the health
monitor verdict (obs/monitor.py) when the suites ran with
``REPRO_MONITOR`` set.  CI's append-and-compare job carries the file
across workflow runs (actions/cache) and uses ``compare`` as the gate:
a monitor violation in the current entry **fails**, a >25% wall-clock
regression vs the previous entry **warns** — perf noise on shared
runners is real, consensus violations are not.

Everything here is stdlib-only on purpose: the gate must run even where
jax is broken.
"""
from __future__ import annotations

import json
import subprocess
from pathlib import Path
from typing import Dict, List, Optional

SCHEMA_VERSION = 1

# numeric per-suite stats copied verbatim from benchmarks/run.py entries
SUITE_STATS = ("wall_s", "compile_s", "run_s", "xla_compile_s",
               "cache_hits", "cache_misses", "cache_saved_s", "traces")


def git_sha(repo_root) -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             cwd=str(repo_root), capture_output=True,
                             text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except OSError:
        return "unknown"


def make_entry(suites: Dict[str, Dict], quick: bool,
               git_sha: str = "unknown", timestamp: float = 0.0) -> Dict:
    """One history entry from benchmarks/run.py per-suite stat dicts.
    Copies the known numeric stats, the error marker, and the monitor
    verdict; ignores anything else so BENCH_core.json bookkeeping churn
    can't silently change the history schema."""
    out_suites: Dict[str, Dict] = {}
    for name, s in suites.items():
        row: Dict = {}
        for k in SUITE_STATS:
            if k in s and s[k] is not None:
                row[k] = round(float(s[k]), 6) if isinstance(
                    s[k], float) else s[k]
        if s.get("error"):
            row["error"] = str(s["error"])
        mon = s.get("monitor")
        if mon is not None:
            row["monitor"] = {"ok": bool(mon.get("ok", False)),
                              "violations": dict(mon.get("violations", {})),
                              "level": mon.get("level"),
                              "points": mon.get("points")}
        # static-analysis rule counts (repro.analysis): active findings
        # per rule at the time of the run, so lint debt is a trajectory
        ana = s.get("analysis")
        if isinstance(ana, dict):
            row["analysis"] = {str(k): int(v) for k, v in ana.items()}
        out_suites[name] = row
    return {"schema": SCHEMA_VERSION, "git_sha": str(git_sha),
            "timestamp": float(timestamp), "quick": bool(quick),
            "suites": out_suites}


def validate_entry(entry: Dict) -> Dict:
    """Schema check; raises ValueError with a pointed message on the
    first violation, returns the entry unchanged otherwise."""
    if not isinstance(entry, dict):
        raise ValueError(f"history entry must be a dict, got {type(entry)}")
    for k in ("schema", "git_sha", "timestamp", "quick", "suites"):
        if k not in entry:
            raise ValueError(f"history entry missing {k!r}")
    if entry["schema"] != SCHEMA_VERSION:
        raise ValueError(f"history schema {entry['schema']!r} != "
                         f"{SCHEMA_VERSION}")
    if not isinstance(entry["suites"], dict) or not entry["suites"]:
        raise ValueError("history entry has no suites")
    for name, s in entry["suites"].items():
        if not isinstance(s, dict):
            raise ValueError(f"suite {name!r} entry must be a dict")
        if "error" not in s:
            if "wall_s" not in s:
                raise ValueError(f"suite {name!r} missing wall_s")
            if not isinstance(s["wall_s"], (int, float)) or s["wall_s"] < 0:
                raise ValueError(f"suite {name!r} wall_s {s['wall_s']!r}")
        mon = s.get("monitor")
        if mon is not None:
            if not isinstance(mon.get("ok"), bool):
                raise ValueError(f"suite {name!r} monitor.ok must be bool")
            if not isinstance(mon.get("violations"), dict):
                raise ValueError(
                    f"suite {name!r} monitor.violations must be a dict")
            if mon["ok"] and any(mon["violations"].values()):
                raise ValueError(
                    f"suite {name!r} monitor ok=True with violations")
        ana = s.get("analysis")
        if ana is not None:
            if not isinstance(ana, dict) or not all(
                    isinstance(v, int) for v in ana.values()):
                raise ValueError(f"suite {name!r} analysis block must "
                                 "map rule -> int count")
    return entry


def append(path, entry: Dict) -> None:
    validate_entry(entry)
    p = Path(path)
    with p.open("a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")


def load(path) -> List[Dict]:
    """All valid entries, oldest first; malformed lines are skipped (the
    ledger outlives schema bumps and interrupted writes)."""
    p = Path(path)
    if not p.exists():
        return []
    out = []
    for line in p.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            out.append(validate_entry(json.loads(line)))
        except (ValueError, json.JSONDecodeError):
            continue
    return out


def latest(path) -> Optional[Dict]:
    entries = load(path)
    return entries[-1] if entries else None


def compare(baseline: Optional[Dict], current: Dict,
            warn_frac: float = 0.25) -> Dict[str, Dict]:
    """Per-suite regression check of ``current`` against ``baseline``.
    Status per suite: ``fail`` (monitor violations — correctness),
    ``warn`` (wall-clock regressed by more than ``warn_frac``, or the
    suite errored), ``ok`` otherwise. Suites absent from the baseline
    compare against nothing and can only fail on their own monitor."""
    out: Dict[str, Dict] = {}
    base_suites = (baseline or {}).get("suites", {})
    for name, cur in current.get("suites", {}).items():
        row: Dict = {"status": "ok"}
        mon = cur.get("monitor")
        if mon is not None:
            row["monitor_ok"] = bool(mon["ok"])
            if not mon["ok"]:
                row["status"] = "fail"
                row["violations"] = dict(mon["violations"])
        if cur.get("error"):
            row["status"] = "fail" if row["status"] == "fail" else "warn"
            row["error"] = cur["error"]
        wall = cur.get("wall_s")
        base_wall = base_suites.get(name, {}).get("wall_s")
        if wall is not None:
            row["wall_s"] = wall
        if wall is not None and base_wall:
            row["base_wall_s"] = base_wall
            row["ratio"] = round(wall / base_wall, 4)
            if row["status"] == "ok" and wall > base_wall * (1 + warn_frac):
                row["status"] = "warn"
        out[name] = row
    return out


def format_compare(cmp: Dict[str, Dict]) -> List[str]:
    """Human lines for benchmark stderr / CI logs, one per suite."""
    lines = []
    for name, row in sorted(cmp.items()):
        bits = [f"{row['status'].upper():4}", name]
        if "ratio" in row:
            bits.append(f"wall {row['wall_s']:.2f}s "
                        f"({row['ratio']:.2f}x baseline)")
        elif "wall_s" in row:
            bits.append(f"wall {row['wall_s']:.2f}s (no baseline)")
        if "violations" in row:
            bits.append("violations " + " ".join(
                f"{k}={v}" for k, v in sorted(row["violations"].items())))
        if "error" in row:
            bits.append(f"error: {row['error']}")
        lines.append("  ".join(bits))
    return lines
