"""Client arrivals (open- or closed-loop) + batch bookkeeping.

Arrivals are Poisson per tick per origin. The mean comes from one of two
statically-selected paths (``repro.workloads.WorkloadMode``):

  trivial — the seed-era §5.2 baseline: ``rate_per_tick`` broadcast to all
            origins, instruction-identical to the original scalar path
            (what keeps the fig 6-9 artifacts byte-identical);
  table   — ``rate_per_tick x rate_of[win_of_tick[t]]`` from a compiled
            ``repro.workloads`` rate table; in closed mode the table
            instead sizes geo-placed client pools (Little's law) whose
            submission rate is gated on in-flight requests and capped at
            ``cap`` outstanding per origin.

Batch records are global arrays indexed [origin, round]:
  create_t   — tick when the batch was formed
  arr_mean   — mean arrival tick of its requests (for execution latency)
  count      — number of requests in the batch
Commit times are reconstructed post-hoc from the per-tick committed-VC
trace (searchsorted), so the hot loop never touches [n, R_MAX] arrays.
The closed-loop in-flight decrement at commit lives in the scan step
(harness._scan_body), which owns the commit signal.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.smr import SMRConfig
from repro.workloads.compile import TRIVIAL_MODE, WorkloadMode


def init_workload(cfg: SMRConfig, n_ticks: int,
                  closed: bool = False) -> Dict[str, jax.Array]:
    n = cfg.n_replicas
    wl = {
        "buffer": jnp.zeros((n,), jnp.float32),        # pending request count
        "buffer_tsum": jnp.zeros((n,), jnp.float32),   # sum of arrival ticks
        "last_batch_t": jnp.zeros((n,), jnp.float32),
        "cpu_tokens": jnp.zeros((n,), jnp.float32),
        "batch_create_t": jnp.full((n, n_ticks), jnp.inf, jnp.float32),
        "batch_arr_mean": jnp.zeros((n, n_ticks), jnp.float32),
        "batch_count": jnp.zeros((n, n_ticks), jnp.float32),
    }
    if closed:
        wl["cl_submitted"] = jnp.zeros((n,), jnp.float32)
        wl["cl_done"] = jnp.zeros((n,), jnp.float32)
        # running prefix sum of batch_count by round (written at formation,
        # rounds are formed in order) so the commit feedback is an O(n)
        # gather per tick instead of an O(n x n_ticks) masked reduction
        wl["batch_count_cum"] = jnp.zeros((n, n_ticks), jnp.float32)
    return wl


def arrive(wl: Dict, key: jax.Array, t: jax.Array, rate_per_tick: jax.Array,
           alive: jax.Array, wlt: Optional[Dict] = None,
           mode: WorkloadMode = TRIVIAL_MODE) -> Dict:
    """Poisson arrivals this tick at each origin's clients. ``wlt`` is the
    compiled workload table (required unless mode.trivial)."""
    wl = dict(wl)
    if mode.trivial:
        lam = jnp.broadcast_to(rate_per_tick, alive.shape)
        cnt = jax.random.poisson(key, lam).astype(jnp.float32) * alive
    else:
        mult = wlt["rate_of"][wlt["win_of_tick"][t]]           # [n]
        lam = rate_per_tick * mult
        if mode.closed:
            # pool size via Little's law at the sweep rate; submission is
            # gated on requests still in flight and capped at `cap`
            inflight = wl["cl_submitted"] - wl["cl_done"]
            clients = rate_per_tick * wlt["think_ticks"] * mult
            lam_cl = jnp.clip(clients - inflight, 0.0) / wlt["think_ticks"]
            lam = jnp.where(wlt["closed"] > 0, lam_cl, lam)
        cnt = jax.random.poisson(key, lam).astype(jnp.float32) * alive
        if mode.closed:
            room = jnp.clip(wlt["cap"] - inflight, 0.0)
            cnt = jnp.where(wlt["closed"] > 0, jnp.minimum(cnt, room), cnt)
            wl["cl_submitted"] = wl["cl_submitted"] + cnt
    wl["buffer"] = wl["buffer"] + cnt
    wl["buffer_tsum"] = wl["buffer_tsum"] + cnt * t
    return wl


def refill_cpu(wl: Dict, cpu_req_per_tick: jax.Array) -> Dict:
    wl = dict(wl)
    wl["cpu_tokens"] = jnp.minimum(wl["cpu_tokens"] + cpu_req_per_tick, 1e7)
    return wl


def form_batches(wl: Dict, t: jax.Array, can_form: jax.Array,
                 round_idx: jax.Array, batch_size: int, batch_ticks: float
                 ) -> Tuple[Dict, jax.Array, jax.Array]:
    """can_form: [n] bool (protocol gate, e.g. ~awaitingAcks & alive).
    round_idx: [n] int32 — the chain round the new batch would get.
    Returns (wl, formed [n] bool, count [n] float)."""
    wl = dict(wl)
    size_ok = wl["buffer"] >= batch_size
    time_ok = (t - wl["last_batch_t"] >= batch_ticks) & (wl["buffer"] > 0)
    formed = can_form & (size_ok | time_ok) & (wl["cpu_tokens"] >= 1.0)
    count = jnp.where(formed,
                      jnp.minimum(jnp.minimum(wl["buffer"], batch_size),
                                  wl["cpu_tokens"]), 0.0)
    frac = jnp.where(wl["buffer"] > 0, count / jnp.maximum(wl["buffer"], 1.0), 0.0)
    tsum_taken = wl["buffer_tsum"] * frac
    arr_mean = jnp.where(count > 0, tsum_taken / jnp.maximum(count, 1.0), 0.0)
    n = count.shape[0]
    rows = jnp.arange(n)
    idx = jnp.clip(round_idx, 0, wl["batch_create_t"].shape[1] - 1)
    wl["batch_create_t"] = wl["batch_create_t"].at[rows, idx].min(
        jnp.where(formed, t.astype(jnp.float32), jnp.inf))
    wl["batch_arr_mean"] = wl["batch_arr_mean"].at[rows, idx].add(
        jnp.where(formed, arr_mean, 0.0))
    wl["batch_count"] = wl["batch_count"].at[rows, idx].add(count)
    if "batch_count_cum" in wl:
        prev = wl["batch_count_cum"][rows, jnp.maximum(idx - 1, 0)]
        wl["batch_count_cum"] = wl["batch_count_cum"].at[rows, idx].set(
            jnp.where(formed, prev + count,
                      wl["batch_count_cum"][rows, idx]))
    wl["buffer"] = wl["buffer"] - count
    wl["buffer_tsum"] = wl["buffer_tsum"] - tsum_taken
    wl["cpu_tokens"] = wl["cpu_tokens"] - count
    wl["last_batch_t"] = jnp.where(formed, t.astype(jnp.float32),
                                   wl["last_batch_t"])
    return wl, formed, count
