"""Tracelint layer 1: call-graph-aware AST lint over ``src/repro``.

Five rules, each a static form of an invariant the test suite currently
re-proves dynamically with whole sweeps (see docs/ARCHITECTURE.md):

  R1 traced-purity   no host numpy / stdlib random / ``.item()`` /
                     ``float()``/``int()`` coercions / ``print`` in any
                     function reachable from a protocol ``tick`` or a
                     ``lax.scan`` body. Deliberate trace-time constants
                     get ``# lint: allow(traced-purity): <why>``.
  R2 dtype-hygiene   no f64 creep toward device buffers: ``np.float64``
                     (or dtype strings / ``astype(float)``) anywhere in
                     simulator source is flagged unless justified.
  R3 static-args     SMRConfig fields steering Python control flow in
                     traced code must be jit-static: the config class is
                     a frozen (hashable) dataclass, some jit declares
                     ``cfg`` in ``static_argnames``, and every
                     ``cfg.<x>`` branched on is a declared field.
  R4 drop-mask       every ``channel.Send`` construction must reach a
                     ``ring_commit(..., drop=...)`` in the same
                     function, and legacy ``ch.send`` call sites must
                     pass ``drop=`` (the PR 2 omission-semantics bug
                     class).
  R5 carry-hygiene   results of level-gated initializers
                     (``init_trace`` / ``init_monitor``) may only enter
                     a state dict behind a None/level guard, so the
                     subtree is structurally absent from the scan carry
                     at ``off``.

The call graph is intra-repo and conservative: bare calls resolve within
the module, ``alias.fn`` through import aliases, and ``obj.method`` only
when exactly one class in the tree defines that method name. Scan roots
are functions named ``tick`` in ``core`` protocol modules, any function
passed to a ``*.scan(...)`` call, and functions marked with a
``# lint: traced-root`` comment.

Stdlib-only (``ast`` + ``pathlib``): this layer runs on every push with
no jax installed.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import (Finding, PragmaTable, Report,
                                     RULE_KEYS)

ALL_RULES = ("R1", "R2", "R3", "R4", "R5")

# numpy attributes that are dtype objects / scalar constants: referencing
# them inside traced code is trace-time-static and never materializes a
# host array (np.float64 is deliberately NOT here — that's R2's beat)
_NP_STATIC_ATTRS = {
    "float32", "float16", "bfloat16", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_", "pi", "inf", "nan",
    "newaxis", "ndarray", "dtype", "integer", "floating",
}
# method names too generic to resolve through the unique-method heuristic
_METHOD_DENY = {
    "get", "items", "keys", "values", "append", "update", "copy", "pop",
    "astype", "at", "add", "set", "max", "min", "sum", "any", "all",
    "mean", "item", "ravel", "reshape", "clip", "sort", "split", "join",
    "format", "startswith", "endswith", "replace", "count", "points",
}
_LEVEL_INITS = {"init_trace", "init_monitor"}


def _qual_chain(node: ast.AST) -> Optional[str]:
    """Flatten a Name/Attribute chain to 'a.b.c' (None if not a chain)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclass
class FuncInfo:
    qual: str                      # e.g. repro.core.mandator.tick
    module: "ModuleInfo"
    node: ast.AST                  # FunctionDef
    calls: List[Tuple[str, int]] = field(default_factory=list)
    is_root: bool = False


class ModuleInfo:
    def __init__(self, name: str, path: Path, relpath: str):
        self.name = name
        self.path = path
        self.relpath = relpath
        source = path.read_text()
        self.tree = ast.parse(source, filename=str(path))
        self.pragmas = PragmaTable(source, relpath)
        self.aliases: Dict[str, str] = {}   # local -> module fullname
        self.symbols: Dict[str, str] = {}   # local -> module.attr fullname
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent

    def ancestors(self, node: ast.AST):
        n = self.parents.get(node)
        while n is not None:
            yield n
            n = self.parents.get(n)


class Index:
    """Two-pass repo index: parse + collect defs, then resolve imports
    and call edges against the collected definitions."""

    def __init__(self, root: Path, rel_to: Path):
        self.root = root
        self.modules: Dict[str, ModuleInfo] = {}
        self.funcs: Dict[str, FuncInfo] = {}
        self.classes: Dict[str, ast.ClassDef] = {}
        self.methods: Dict[str, List[str]] = {}  # method name -> quals
        for path in sorted(root.rglob("*.py")):
            name = self._module_name(path)
            rel = path.relative_to(rel_to).as_posix() \
                if rel_to in path.parents or rel_to == path.parent \
                or rel_to in path.resolve().parents else str(path)
            mod = ModuleInfo(name, path, rel)
            self.modules[name] = mod
            self._collect_defs(mod)
        for mod in self.modules.values():
            self._collect_imports(mod)
        for fn in self.funcs.values():
            self._collect_calls(fn)
        self._mark_roots()

    def _module_name(self, path: Path) -> str:
        rel = path.relative_to(self.root).with_suffix("")
        parts = list(rel.parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        # anchor at the import root: src/repro/... lints as repro....
        prefix = [self.root.name] if self.root.name != "src" else []
        return ".".join(prefix + parts) if (prefix or parts) else "_"

    def _collect_defs(self, mod: ModuleInfo) -> None:
        def visit(node: ast.AST, scope: str) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    qual = f"{scope}.{child.name}"
                    self.funcs[qual] = FuncInfo(qual, mod, child)
                    visit(child, qual)
                elif isinstance(child, ast.ClassDef):
                    qual = f"{scope}.{child.name}"
                    self.classes[qual] = child
                    for item in child.body:
                        if isinstance(item, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                            mq = f"{qual}.{item.name}"
                            self.funcs[mq] = FuncInfo(mq, mod, item)
                            self.methods.setdefault(item.name,
                                                    []).append(mq)
                            visit(item, mq)
                else:
                    visit(child, scope)
        visit(mod.tree, mod.name)

    def _collect_imports(self, mod: ModuleInfo) -> None:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mod.aliases[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
                    if a.asname is None and "." in a.name:
                        # `import a.b.c` binds `a`; keep the full path
                        # resolvable through the dotted chain too
                        mod.aliases[a.name] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                base = node.module
                for a in node.names:
                    local = a.asname or a.name
                    full = f"{base}.{a.name}"
                    if full in self.modules or base in ("numpy", "jax"):
                        mod.aliases[local] = full
                    else:
                        mod.symbols[local] = full

    def resolve_module(self, mod: ModuleInfo, chain: str) -> Optional[str]:
        """Longest prefix of a dotted chain that names a module; returns
        the full chain rewritten onto the real module name."""
        parts = chain.split(".")
        for cut in range(len(parts), 0, -1):
            prefix = ".".join(parts[:cut])
            if prefix in mod.aliases:
                return ".".join([mod.aliases[prefix]] + parts[cut:])
        return None

    def resolve_call(self, fn: FuncInfo, call: ast.Call) -> Optional[str]:
        mod = fn.module
        chain = _qual_chain(call.func)
        if chain is None:
            return None
        if "." not in chain:
            # bare call: local symbol import, then same module / class
            if chain in mod.symbols:
                tgt = mod.symbols[chain]
                if tgt in self.funcs or tgt in self.classes:
                    return tgt
                return None
            for qual in (f"{mod.name}.{chain}",):
                if qual in self.funcs or qual in self.classes:
                    return qual
            # nested helper of an enclosing function scope
            scope = fn.qual
            while "." in scope:
                scope = scope.rsplit(".", 1)[0]
                qual = f"{scope}.{chain}"
                if qual in self.funcs:
                    return qual
            return None
        resolved = self.resolve_module(mod, chain)
        if resolved is not None:
            if resolved in self.funcs or resolved in self.classes:
                return resolved
            return None
        # obj.method: unique-method heuristic
        attr = chain.rsplit(".", 1)[1]
        cands = self.methods.get(attr, [])
        if attr not in _METHOD_DENY and len(cands) == 1:
            return cands[0]
        return None

    def _collect_calls(self, fn: FuncInfo) -> None:
        for node in self._own_body(fn.node):
            if isinstance(node, ast.Call):
                tgt = self.resolve_call(fn, node)
                if tgt is not None:
                    if tgt in self.classes:
                        tgt = f"{tgt}.__init__"
                        if tgt not in self.funcs:
                            continue
                    fn.calls.append((tgt, node.lineno))

    @staticmethod
    def _own_body(func_node: ast.AST):
        """Walk a function body without descending into nested defs
        (lambdas stay: they trace inline with their enclosing body)."""
        stack = list(ast.iter_child_nodes(func_node))
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            yield node
            stack.extend(ast.iter_child_nodes(node))

    def _mark_roots(self) -> None:
        for fn in self.funcs.values():
            node = fn.node
            # protocol tick bodies: core/<protocol>.py tick()
            if (node.name == "tick" and ".core." in f".{fn.qual}."
                    and fn.qual.count(".") >= 2):
                fn.is_root = True
            marker_lines = set(fn.module.pragmas.roots)
            if {node.lineno, node.lineno - 1} & marker_lines:
                fn.is_root = True
        # any function passed (positionally first) to a *.scan(...) call
        for holder in list(self.funcs.values()):
            for node in self._own_body(holder.node):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "scan" and node.args):
                    continue
                arg = node.args[0]
                if isinstance(arg, ast.Name):
                    for scope in (holder.qual, holder.module.name):
                        qual = f"{scope}.{arg.id}"
                        if qual in self.funcs:
                            self.funcs[qual].is_root = True
                            break

    def reachable(self) -> Set[str]:
        seen: Set[str] = set()
        stack = [q for q, f in self.funcs.items() if f.is_root]
        while stack:
            q = stack.pop()
            if q in seen:
                continue
            seen.add(q)
            stack.extend(tgt for tgt, _ in self.funcs[q].calls
                         if tgt not in seen)
        return seen


def _emit(report: Report, mod: ModuleInfo, rule: str, node: ast.AST,
          message: str, severity: str = "error") -> None:
    key = RULE_KEYS[rule]
    line = getattr(node, "lineno", 0)
    pragma = mod.pragmas.lookup(line, key)
    report.findings.append(Finding(
        rule=rule, key=key, file=mod.relpath, line=line,
        col=getattr(node, "col_offset", 0), severity=severity,
        message=message,
        pragma="allowed" if pragma and pragma.justification else "none"))


# --------------------------------------------------------------- R1

def _check_r1(index: Index, report: Report) -> None:
    reached = index.reachable()
    for qual in sorted(reached):
        fn = index.funcs[qual]
        mod = fn.module
        for node in Index._own_body(fn.node):
            if isinstance(node, ast.Attribute):
                chain = _qual_chain(node)
                if chain is None:
                    continue
                base = index.resolve_module(mod, chain)
                if base is None:
                    continue
                root_pkg = base.split(".")[0]
                if root_pkg == "numpy" and \
                        base.split(".")[-1] not in _NP_STATIC_ATTRS:
                    _emit(report, mod, "R1", node,
                          f"host numpy in traced code: `{chain}` is "
                          f"reachable from a scan/tick root via {qual}")
                elif root_pkg == "random":
                    _emit(report, mod, "R1", node,
                          f"stdlib random in traced code: `{chain}` "
                          f"(reachable via {qual}) — use jax.random")
            elif isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr == "item" \
                        and not node.args and not node.keywords:
                    _emit(report, mod, "R1", node,
                          ".item() forces a device sync inside traced "
                          f"code (reachable via {qual})")
                elif isinstance(f, ast.Name) and f.id in ("float", "int"):
                    _emit(report, mod, "R1", node,
                          f"`{f.id}()` coercion in traced code forces a "
                          f"host round-trip (reachable via {qual})")
                elif isinstance(f, ast.Name) and f.id == "print":
                    _emit(report, mod, "R1", node,
                          "print() in traced code runs at trace time "
                          f"only / forces host callbacks (via {qual})")


# --------------------------------------------------------------- R2

def _check_r2(index: Index, report: Report) -> None:
    for mod in index.modules.values():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and \
                    node.attr in ("float64", "double"):
                chain = _qual_chain(node)
                base = index.resolve_module(mod, chain) if chain else None
                if base and base.split(".")[0] in ("numpy", "jax"):
                    _emit(report, mod, "R2", node,
                          f"`{chain}`: f64 dtype feeding simulator "
                          "buffers (device programs are f32-only)")
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "dtype" and (
                            isinstance(kw.value, ast.Name)
                            and kw.value.id in ("float", "int")):
                        _emit(report, mod, "R2", kw.value,
                              f"dtype={kw.value.id} is platform f64/i64 "
                              "— name an explicit 32-bit dtype")
                    elif kw.arg == "dtype" and (
                            isinstance(kw.value, ast.Constant)
                            and kw.value.value == "float64"):
                        _emit(report, mod, "R2", kw.value,
                              'dtype="float64" feeding simulator '
                              "buffers (device programs are f32-only)")
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr == "astype" \
                        and node.args:
                    a = node.args[0]
                    if isinstance(a, ast.Name) and a.id in ("float",
                                                            "int"):
                        _emit(report, mod, "R2", node,
                              f"astype({a.id}) widens to f64/i64 — "
                              "name an explicit 32-bit dtype")


# --------------------------------------------------------------- R3

def _check_r3(index: Index, report: Report) -> None:
    cfg_fields: Set[str] = set()
    cfg_class: Optional[Tuple[ModuleInfo, ast.ClassDef]] = None
    for qual, cls in index.classes.items():
        if cls.name != "SMRConfig":
            continue
        mod = index.modules[qual.rsplit(".", 1)[0]]
        cfg_class = (mod, cls)
        frozen = False
        for dec in cls.decorator_list:
            if isinstance(dec, ast.Call) and \
                    _qual_chain(dec.func) in ("dataclass",
                                              "dataclasses.dataclass"):
                for kw in dec.keywords:
                    if kw.arg == "frozen" and \
                            isinstance(kw.value, ast.Constant):
                        frozen = bool(kw.value.value)
        if not frozen:
            _emit(report, mod, "R3", cls,
                  "SMRConfig must be @dataclass(frozen=True): only a "
                  "hashable config can be a jit static argument")
        for item in cls.body:
            if isinstance(item, ast.AnnAssign) and \
                    isinstance(item.target, ast.Name):
                cfg_fields.add(item.target.id)
                if isinstance(item.value, (ast.List, ast.Dict, ast.Set)):
                    _emit(report, mod, "R3", item,
                          f"SMRConfig.{item.target.id} has a mutable "
                          "(unhashable) default — jit-static configs "
                          "need hashable fields")
    if cfg_class is None:
        return
    # is `cfg` declared jit-static anywhere?
    static_ok = False
    for mod in index.modules.values():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "static_argnames":
                        names = [e.value for e in ast.walk(kw.value)
                                 if isinstance(e, ast.Constant)]
                        if "cfg" in names:
                            static_ok = True
    if not static_ok:
        mod, cls = cfg_class
        _emit(report, mod, "R3", cls,
              "no jit static_argnames declaration includes 'cfg' — "
              "config-steered Python control flow would retrace or fail")
    # cfg.<x> steering control flow in traced-reachable code must name a
    # declared (static, hashable) SMRConfig field — but only where `cfg`
    # actually binds an SMRConfig (own or enclosing-scope parameter
    # annotation; other config families are out of scope)
    def _binds_smr_cfg(fn: FuncInfo) -> bool:
        qual = fn.qual
        while qual in index.funcs:
            node = index.funcs[qual].node
            for a in node.args.args + node.args.kwonlyargs:
                if a.arg != "cfg":
                    continue
                ann = a.annotation
                if ann is None:
                    return "SMRConfig" in fn.module.symbols or any(
                        v.endswith(".SMRConfig")
                        for v in fn.module.symbols.values())
                name = ann.value if isinstance(ann, ast.Constant) \
                    else _qual_chain(ann)
                return bool(name) and str(name).split(".")[-1] == \
                    "SMRConfig"
            qual = qual.rsplit(".", 1)[0]
        return False

    reached = index.reachable()
    for qual in sorted(reached):
        fn = index.funcs[qual]
        if not _binds_smr_cfg(fn):
            continue
        tests: List[ast.AST] = []
        for node in Index._own_body(fn.node):
            if isinstance(node, (ast.If, ast.While)):
                tests.append(node.test)
            elif isinstance(node, ast.IfExp):
                tests.append(node.test)
            elif isinstance(node, ast.Assert):
                tests.append(node.test)
        for test in tests:
            for sub in ast.walk(test):
                if isinstance(sub, ast.Attribute) and \
                        isinstance(sub.value, ast.Name) and \
                        sub.value.id == "cfg" and \
                        sub.attr not in cfg_fields:
                    _emit(report, fn.module, "R3", sub,
                          f"cfg.{sub.attr} steers Python control flow "
                          f"in traced code ({qual}) but is not a "
                          "declared SMRConfig field — undeclared "
                          "statics break the one-program contract")


# --------------------------------------------------------------- R4

def _check_r4(index: Index, report: Report) -> None:
    for fn in index.funcs.values():
        mod = fn.module
        sends: List[ast.Call] = []
        commits: List[ast.Call] = []
        legacy: List[ast.Call] = []
        for node in Index._own_body(fn.node):
            if not isinstance(node, ast.Call):
                continue
            chain = _qual_chain(node.func)
            if chain is None:
                continue
            tail = chain.split(".")[-1]
            tgt = index.resolve_call(fn, node)
            if tgt:
                tgt = tgt.rsplit(".__init__", 1)[0]
            if tail == "Send" and tgt and tgt.split(".")[-1] == "Send":
                sends.append(node)
            elif tail == "ring_commit":
                # only commits whose callee actually takes a drop mask:
                # the kernel-level ops.ring_commit runs post-merge
                callee = index.funcs.get(tgt) if tgt else None
                if callee is None or any(
                        a.arg == "drop" for a in
                        callee.node.args.args + callee.node.args.kwonlyargs):
                    commits.append(node)
            elif tail == "send" and tgt and \
                    tgt.split(".")[-1] == "send":
                legacy.append(node)
        for call in commits:
            if not any(kw.arg == "drop" for kw in call.keywords):
                _emit(report, mod, "R4", call,
                      "ring_commit without drop= — sends bypass the "
                      "scenario drop mask (silent-omission semantics)")
        for call in legacy:
            if not any(kw.arg == "drop" for kw in call.keywords):
                _emit(report, mod, "R4", call,
                      "channel.send without drop= — the env drop mask "
                      "must thread through every send path")
        if sends and not commits and not legacy:
            _emit(report, mod, "R4", sends[0],
                  "channel.Send constructed here but never committed "
                  "via ring_commit(..., drop=...) in this function — "
                  "the drop mask cannot thread through")


# --------------------------------------------------------------- R5

def _guard_mentions(mod: ModuleInfo, test: ast.AST, names: Set[str],
                    guard_vars: Set[str]) -> bool:
    for sub in ast.walk(test):
        if isinstance(sub, ast.Name) and (sub.id in names
                                          or sub.id in guard_vars):
            return True
        if isinstance(sub, ast.Call) and \
                isinstance(sub.func, ast.Attribute) and \
                sub.func.attr == "on":
            return True
    return False


def _check_r5(index: Index, report: Report) -> None:
    for fn in index.funcs.values():
        mod = fn.module
        optional_vars: Set[str] = set()
        guard_vars: Set[str] = set()
        init_calls: List[ast.Call] = []
        for node in Index._own_body(fn.node):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call):
                chain = _qual_chain(node.value.func) or ""
                tail = chain.split(".")[-1]
                tgt = [t.id for t in node.targets
                       if isinstance(t, ast.Name)]
                if tail in _LEVEL_INITS and tgt:
                    optional_vars.update(tgt)
                elif tail == "on" and tgt:
                    guard_vars.update(tgt)
            if isinstance(node, ast.Call):
                chain = _qual_chain(node.func) or ""
                if chain.split(".")[-1] in _LEVEL_INITS:
                    init_calls.append(node)
        if not optional_vars and not init_calls:
            continue

        def guarded(node: ast.AST, names: Set[str]) -> bool:
            for anc in mod.ancestors(node):
                if isinstance(anc, ast.IfExp) and \
                        _guard_mentions(mod, anc.test, names, guard_vars):
                    return True
                if isinstance(anc, ast.If) and \
                        _guard_mentions(mod, anc.test, names, guard_vars):
                    return True
                if isinstance(anc, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                    break
            return False

        for node in Index._own_body(fn.node):
            # dict-literal values carrying the optional subtree
            if isinstance(node, ast.Dict):
                for v in node.values:
                    stored = (isinstance(v, ast.Name)
                              and v.id in optional_vars) or \
                             (isinstance(v, ast.Call) and
                              (_qual_chain(v.func) or "")
                              .split(".")[-1] in _LEVEL_INITS)
                    if stored and not guarded(node, optional_vars):
                        _emit(report, mod, "R5", v,
                              "level-gated subtree stored in a carry "
                              "dict without a None/level guard — it "
                              "would ride the scan carry even at off")
            # subscript stores: st["tr"] = tr / = init_trace(...)
            elif isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Subscript) for t in node.targets):
                v = node.value
                stored = (isinstance(v, ast.Name)
                          and v.id in optional_vars) or \
                         (isinstance(v, ast.Call) and
                          (_qual_chain(v.func) or "")
                          .split(".")[-1] in _LEVEL_INITS)
                if stored and not guarded(node, optional_vars):
                    _emit(report, mod, "R5", node,
                          "level-gated subtree assigned into state "
                          "without a None/level guard — it would ride "
                          "the scan carry even at off")


_CHECKS = {"R1": _check_r1, "R2": _check_r2, "R3": _check_r3,
           "R4": _check_r4, "R5": _check_r5}


def run_lint(root: Path, rules=None, rel_to: Optional[Path] = None) \
        -> Report:
    """Lint every ``*.py`` under ``root``; returns the Report (pragma
    findings included). ``rules`` restricts to a subset of R1–R5."""
    root = Path(root)
    if rel_to is None:
        rel_to = root.parents[1] if root.parent.name == "src" else root
    index = Index(root, rel_to)
    report = Report()
    for rule in (rules or ALL_RULES):
        _CHECKS[rule](index, report)
    for mod in index.modules.values():
        report.extend(mod.pragmas.pragma_findings())
    return report
