"""Optimizer (incl. int8 state + error-feedback compression), data pipeline,
checkpoint commit-cut tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # degrade: only property tests skip
    HAVE_HYPOTHESIS = False

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, batch_shard, global_batch
from repro.optim.adamw import (AdamWConfig, apply_updates, compress_grad,
                               decompress_grad, init_opt_state)

KEY = jax.random.PRNGKey(0)


def test_adamw_matches_reference_math():
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9,
                      warmup_steps=1)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st_ = init_opt_state(cfg, p)
    p2, st2, _ = apply_updates(cfg, p, g, st_)
    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.05 * np.array([0.1, 0.2, -0.3]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    expect = np.array([1.0, -2.0, 3.0]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-5)


@pytest.mark.parametrize("quantized", [False, True])
def test_optimizer_reduces_quadratic_loss(quantized):
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, quantized_state=quantized,
                      warmup_steps=1)
    target = jnp.linspace(-1, 1, 512)
    p = {"w": jnp.zeros(512)}
    st_ = init_opt_state(cfg, p)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(p))
    for _ in range(60):
        g = jax.grad(loss)(p)
        p, st_, _ = apply_updates(cfg, p, g, st_)
    assert float(loss(p)) < 0.05 * l0


def test_grad_compression_error_feedback():
    g = jax.random.normal(KEY, (1024,)) * 0.3
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    acc_true = jnp.zeros_like(g)
    for i in range(20):
        gi = g * (1 + 0.1 * i)
        q, s, err = compress_grad(gi, err)
        acc = acc + decompress_grad(q, s, gi.shape, gi.size)
        acc_true = acc_true + gi
    # error feedback keeps the accumulated error bounded (last residual)
    rel = float(jnp.linalg.norm(acc - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 1e-2


def test_data_pipeline_determinism_and_sharding():
    cfg = get_config("smollm-135m").reduced()
    shape = ShapeConfig("t", "train", 32, 8)
    dcfg = DataConfig(seed=3)
    a = global_batch(cfg, shape, dcfg, step=5)
    b = global_batch(cfg, shape, dcfg, step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = global_batch(cfg, shape, dcfg, step=6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shards are distinct and deterministic
    s0 = batch_shard(cfg, shape, dcfg, 5, 0, 4)
    s1 = batch_shard(cfg, shape, dcfg, 5, 1, 4)
    assert s0["tokens"].shape == (2, 32)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_checkpoint_roundtrip_and_commit_cut(tmp_path):
    from repro.checkpoint.checkpoint import MandatorCheckpointer
    ck = MandatorCheckpointer(tmp_path, n_controllers=3)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    # only 1 of 3 shards written -> no commit (torn checkpoint impossible)
    ck.write_shard(0, 1, tree)
    assert not ck.try_commit(1, step=10)
    assert ck.latest_committed() is None
    ck.write_shard(1, 1, tree)
    assert ck.try_commit(1, step=10)       # quorum (2 of 3) -> commit
    step, restored = ck.restore(tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    # newer committed version wins
    tree2 = {"a": 2 * jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": {"c": jnp.zeros((4,), jnp.int32)}}
    for c in range(3):
        ck.write_shard(c, 2, tree2)
    ck.try_commit(2, step=20)
    step, restored = ck.restore(tree)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.zeros(4))


def test_checkpoint_quantized_opt_state_roundtrip(tmp_path):
    from repro.checkpoint.checkpoint import restore, save
    from repro.optim.adamw import QUANT_MIN_SIZE
    cfg = AdamWConfig(quantized_state=True)
    # one leaf big enough to quantize, one small (stays fp32)
    p = {"w": jax.random.normal(KEY, (QUANT_MIN_SIZE // 1024, 1024)),
         "b": jax.random.normal(KEY, (300,))}
    st_ = init_opt_state(cfg, p)
    assert isinstance(st_["m"]["w"], dict)          # quantized
    assert not isinstance(st_["m"]["b"], dict)      # fp32
    assert st_["m"]["w"]["q"].shape == p["w"].shape  # param-aligned layout
    g = {"w": jnp.ones_like(p["w"]) * 0.1, "b": jnp.ones(300) * 0.1}
    p2, st2, _ = apply_updates(cfg, p, g, st_)
    save(tmp_path / "ck", 7, p2, st2)
    out = restore(tmp_path / "ck", p2, st2)
    assert out is not None
    step, rp, ro = out
    assert step == 7
    np.testing.assert_array_equal(np.asarray(rp["w"]), np.asarray(p2["w"]))
    np.testing.assert_array_equal(np.asarray(ro["m"]["w"]["q"]),
                                  np.asarray(st2["m"]["w"]["q"]))


def _shard_union_case(step, n_shards):
    """Shards always tile the global batch deterministically."""
    cfg = get_config("smollm-135m").reduced()
    if 8 % n_shards:
        n_shards = 1
    shape = ShapeConfig("t", "train", 16, 8)
    dcfg = DataConfig(seed=1)
    shards = [batch_shard(cfg, shape, dcfg, step, i, n_shards)
              for i in range(n_shards)]
    total = sum(s["tokens"].shape[0] for s in shards)
    assert total == 8
    again = batch_shard(cfg, shape, dcfg, step, 0, n_shards)
    np.testing.assert_array_equal(shards[0]["tokens"], again["tokens"])


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 1000), st.integers(1, 8))
    def test_pipeline_shard_union_property(step, n_shards):
        _shard_union_case(step, n_shards)
else:
    def test_pipeline_shard_union_property():
        """Degraded fixed-case variant (hypothesis not installed —
        pip install -r requirements-dev.txt for the property test)."""
        for step, n_shards in ((0, 1), (7, 2), (999, 8), (13, 5)):
            _shard_union_case(step, n_shards)
