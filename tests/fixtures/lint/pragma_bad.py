"""P0: a pragma without a justification is itself a finding."""
import numpy as np


# lint: allow(traced-purity)
def helper(x):  # expect: P0
    return np.log(x)
