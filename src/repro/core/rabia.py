"""Rabia baseline — analytic model (documented simplification, DESIGN.md §8).

Rabia (SOSP'21) commits a slot only when a majority of replicas propose the
*same* head-of-queue batch; in a LAN that holds (synchronized arrival), in
the WAN it requires the oldest uncommitted batch to have propagated to a
majority before the slot starts — and each weak-MVC slot costs ~2.5 majority
RTTs. We simulate slot-by-slot over the real batch streams:

- batches form per replica at min(arrival, CPU) into batches of 300;
- slot s (duration 2.5 x median majority RTT) commits the globally oldest
  uncommitted batch iff it is known to >= majority replicas at slot start
  (creation + one-way delay), else the slot is a NULL round (Ben-Or coin
  retry) — reproducing the ~500 tx/s WAN collapse of Fig. 6.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.configs.smr import SMRConfig
from repro.obs import monitor as hmon
from repro.obs.decode import host_phases
from repro.obs.trace import HostTrace, TraceLevel
from repro.workloads.analytic import (
    TableRate,
    closed_equilibrium_rate,
    host_rate,
)


def run_rabia_model(cfg: SMRConfig, rate_tx_s: float, scenario=None,
                    workload=None) -> Dict:
    """``workload``: a repro.workloads.Workload (or None). Open-loop shapes
    make the batch streams time-varying through the compiled rate table;
    closed-loop pools are approximated at their Little's-law equilibrium
    (measure latency open, re-run at the sustainable rate)."""
    wl_rate, closed = host_rate(cfg, workload)
    if closed is not None:
        first = _rabia_once(cfg, rate_tx_s, wl_rate)
        rate_eff = closed_equilibrium_rate(rate_tx_s, closed,
                                           first["median_ms"],
                                           cfg.n_replicas)
        out = _rabia_once(cfg, rate_eff, wl_rate)
        out["rate"] = rate_tx_s
        return out
    return _rabia_once(cfg, rate_tx_s, wl_rate)


def _rabia_once(cfg: SMRConfig, rate_tx_s: float,
                wl_rate: Optional[TableRate] = None) -> Dict:
    n = cfg.n_replicas
    d = cfg.delays_ms()
    maj = n // 2 + 1
    maj_rtt = np.median(np.sort(2 * d, axis=1)[:, maj - 1])
    slot_ms = 2.5 * maj_rtt
    # propagation time of a batch from origin i to a majority
    prop_ms = np.sort(d, axis=1)[:, maj - 1]

    sim_ms = cfg.sim_seconds * 1000.0
    lam = rate_tx_s / n / 1000.0
    batch = cfg.batch_rabia
    streams = []
    for i in range(n):
        t = 0.0
        while t < sim_ms:
            lam_t = lam if wl_rate is None else lam * float(wl_rate.at(t)[i])
            if wl_rate is not None and lam_t <= 0.0:
                # zero-rate window: no arrivals — resume the stream at the
                # window's end instead of dividing by ~0 past the sim
                t = max(wl_rate.next_change_ms(t), t + cfg.tick_ms)
                continue
            fill = max(batch / max(lam_t, 1e-9), cfg.max_batch_ms)
            t += fill
            streams.append((t, i, min(batch, lam_t * fill)))
    streams.sort()
    committed = 0.0
    lat, wt = [], []
    nbuck = int(np.ceil(sim_ms / 500.0))
    timeline = np.zeros(nbuck)
    # flight recorder (host-side twin of repro.obs): one commit event per
    # committed slot, one view_change per NULL (Ben-Or coin) round
    tr = None if cfg.trace_level == TraceLevel.OFF else HostTrace()
    # phase accounting (analytic twin of harness._phase_breakdown):
    # dissemination = propagation to a majority, consensus = the slot
    # wait + 2.5-RTT weak-MVC rounds (the remainder of the latency)
    phases = {"dissemination": [], "consensus": []} if tr is not None \
        else None
    ptr = 0
    slot_idx = 0
    null_slots = 0
    commit_ts = []
    t_slot = slot_ms
    while t_slot < sim_ms and ptr < len(streams):
        create, origin, cnt = streams[ptr]
        if create + prop_ms[origin] <= t_slot:   # majority knows the head
            t_end = t_slot + slot_ms
            if t_end < sim_ms:
                committed += cnt
                commit_ts.append(t_end)
                lat.append(t_end - create)
                wt.append(cnt)
                timeline[int(t_end // 500)] += cnt
                if tr is not None:
                    tr.record("commit", t_end / cfg.tick_ms, who=origin,
                              key=slot_idx, total=cnt)
                    diss = min(prop_ms[origin], t_end - create)
                    phases["dissemination"].append(diss)
                    phases["consensus"].append(t_end - create - diss)
            ptr += 1
        else:
            # NULL slot (coin round commits nothing)
            null_slots += 1
            if tr is not None:
                tr.record("view_change", t_slot / cfg.tick_ms,
                          view=slot_idx, round=0)
        t_slot += slot_ms
        slot_idx += 1
    lat, wt = np.array(lat), np.array(wt)
    med = p99 = float("nan")
    if len(lat):
        order = np.argsort(lat)
        cum = np.cumsum(wt[order]) / wt.sum()
        med = float(lat[order][np.searchsorted(cum, 0.5)])
        p99 = float(lat[order][min(np.searchsorted(cum, 0.99), len(lat) - 1)])
    out = {"protocol": "rabia", "rate": rate_tx_s,
           "throughput": committed / (sim_ms / 1000.0),
           "median_ms": med, "p99_ms": p99, "committed": committed,
           "timeline": timeline / 0.5}
    if tr is not None:
        out["host_trace"] = {
            "counts": tr.counts(),
            "events": tr.events if cfg.trace_level == TraceLevel.FULL
            else []}
        out.update(host_phases(phases, wt))
    if hmon.on(cfg.monitor_level):
        # host twin of the device monitor: slots commit one batch each in
        # strictly increasing slot time (a backwards commit would break
        # prefix order), never more than was offered; NULL-round fraction
        # is THE Rabia starvation gauge (the WAN-collapse mechanism)
        offered = rate_tx_s * sim_ms / 1000.0
        out["monitor"] = hmon.host_verdict(
            violations={
                "commit_once": int(committed > offered * 1.01 + 1.0),
                "prefix": sum(1 for a, b in zip(commit_ts, commit_ts[1:])
                              if b <= a),
            },
            gauges={"null_slots": int(null_slots),
                    "null_frac": round(null_slots / max(slot_idx, 1), 4),
                    "backlog": int(len(streams) - ptr)},
            level=cfg.monitor_level)
    return out
