"""Tracelint tests: the fixture corpus (each AST rule trips exactly once
at the marked span), pragma semantics, the repo-clean self-check, the
baseline round-trip, CLI exit codes, and a warm-cache HLO audit.

The corpus under ``tests/fixtures/lint/`` carries ``# expect: <RULE>``
markers on the lines each rule must flag — the tests derive the expected
(file, line) spans from those markers so fixture edits can't silently
drift from the assertions.
"""
import json
import re
from pathlib import Path

import pytest

from repro.analysis import format_table, run_lint
from repro.analysis.__main__ import main as cli_main
from repro.analysis.findings import PragmaTable, findings_from_json

FIXTURES = Path(__file__).parent / "fixtures" / "lint"
SRC_REPRO = Path(__file__).resolve().parents[1] / "src" / "repro"

_EXPECT_RE = re.compile(r"#\s*expect:\s*(\w+)")


def _expected_spans():
    """{(rule, file): line} from the corpus ``# expect:`` markers."""
    out = {}
    for path in sorted(FIXTURES.glob("*.py")):
        for lineno, text in enumerate(path.read_text().splitlines(), 1):
            m = _EXPECT_RE.search(text)
            if m:
                out[(m.group(1), path.name)] = lineno
    return out


@pytest.fixture(scope="module")
def corpus():
    return run_lint(FIXTURES)


def test_every_marked_span_trips_exactly_once(corpus):
    expected = _expected_spans()
    assert expected, "fixture corpus has no # expect: markers"
    active = [(f.rule, f.file, f.line) for f in corpus.active]
    for (rule, fname), line in expected.items():
        hits = [a for a in active if a[0] == rule and a[1] == fname]
        assert hits == [(rule, fname, line)], \
            f"{rule} in {fname}: expected one finding at line {line}, " \
            f"got {hits}"
    # and nothing beyond the marked spans is active
    assert len(active) == len(expected), active


def test_rules_trip_only_in_their_fixture(corpus):
    for rule in ("R1", "R2", "R3", "R4", "R5"):
        files = {f.file for f in corpus.active if f.rule == rule}
        assert files == {f"{rule.lower()}_bad.py"}, (rule, files)


def test_good_file_clean_with_one_allowed_pragma(corpus):
    good = [f for f in corpus.findings if f.file == "good.py"]
    assert not [f for f in good if f.active]
    allowed = [f for f in good if f.pragma == "allowed"]
    assert len(allowed) == 1 and allowed[0].rule == "R1"


def test_unjustified_pragma_is_a_finding(corpus):
    p0 = [f for f in corpus.active if f.rule == "P0"]
    assert len(p0) == 1 and p0[0].file == "pragma_bad.py"


def test_rule_subset_runs_only_those_rules():
    report = run_lint(FIXTURES, rules=("R2",))
    rules = {f.rule for f in report.active}
    assert rules == {"R2", "P0"}  # pragma findings always reported


def test_repo_is_clean():
    """The self-check ISSUE 9 gates on: src/repro lints with zero active
    findings, and every suppression carries a justification."""
    report = run_lint(SRC_REPRO)
    assert report.active == [], "\n".join(format_table(report.active))
    for f in report.findings:
        assert f.pragma == "allowed", f


def test_pragma_table_same_line_and_comment_above():
    src = ("x = 1  # lint: allow(dtype-hygiene): same-line case\n"
           "# lint: allow(drop-mask): comment-above case\n"
           "y = 2\n"
           "# lint: allow(carry-hygiene)\n"
           "z = 3\n")
    t = PragmaTable(src, "t.py")
    assert t.lookup(1, "dtype-hygiene").justification
    assert t.lookup(3, "drop-mask").justification
    assert t.lookup(3, "dtype-hygiene") is None   # key must match
    p0 = t.pragma_findings()
    assert len(p0) == 1 and p0[0].line == 5       # unjustified one


def test_baseline_roundtrip(tmp_path, corpus):
    base = tmp_path / "baseline.json"
    base.write_text(json.dumps(corpus.baseline_json()))
    fresh = run_lint(FIXTURES)
    from repro.analysis.findings import load_baseline
    fresh.apply_baseline(load_baseline(base))
    assert fresh.active == []
    assert sum(1 for f in fresh.findings
               if f.pragma == "baselined") == len(corpus.active)


def test_cli_exit_codes_and_json_artifact(tmp_path, capsys):
    art = tmp_path / "findings.json"
    rc = cli_main(["--root", str(FIXTURES), "--quiet",
                   "--json", str(art)])
    assert rc == 1  # corpus has active findings
    findings = findings_from_json(json.loads(art.read_text()))
    assert sum(1 for f in findings if f.active) == 6
    # baselining every active finding turns the run green
    base = tmp_path / "base.json"
    assert cli_main(["--root", str(FIXTURES), "--quiet",
                     "--update-baseline", str(base)]) == 0
    assert cli_main(["--root", str(FIXTURES), "--quiet",
                     "--baseline", str(base)]) == 0
    # the repo itself is the CLI's default root and must be green
    assert cli_main(["--quiet"]) == 0
    capsys.readouterr()


def test_hlo_audit_single_protocol(tmp_path):
    """End-to-end layer 2 on one protocol at the canonical --quick length
    (warm .jax_cache in CI; the in-process jit cache covers reruns)."""
    from repro.analysis import hlo_lint
    from repro.obs import history

    verdict = hlo_lint.audit(protocols=("mandator", "epaxos"),
                             sim_seconds=2.0)
    assert verdict["ok"], verdict["violations"]
    m = verdict["protocols"]["mandator"]
    assert m["f64_ops"] == 0
    assert m["host_transfers_in_loop"] == 0
    assert m["scan_whiles"] == 1
    assert verdict["protocols"]["epaxos"]["program"] is None
    for sigs in verdict["signatures"].values():
        assert len(sigs) == 1           # H4: one signature per mode
    # verdict rides the history ledger and gates like a monitor verdict
    ledger = tmp_path / "hist.jsonl"
    hlo_lint.append_history(ledger, verdict,
                            analysis_counts={"active": 0})
    (entry,) = history.load(ledger)
    suite = entry["suites"]["hlo-audit"]
    assert suite["monitor"]["ok"] is True
    assert suite["monitor"]["level"] == "hlo"
    assert suite["analysis"] == {"active": 0}
    cmp_res = history.compare(None, entry)
    assert cmp_res["hlo-audit"]["status"] == "ok"
