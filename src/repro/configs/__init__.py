"""Arch registry: ``get_config(name)`` / ``list_archs()`` / ``iter_cells()``."""
from __future__ import annotations

import importlib
from typing import Dict, Iterator, Tuple

from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, CrossAttnConfig,
    ShapeConfig, SHAPES, shape_supported, param_count,
)

_ARCH_MODULES: Dict[str, str] = {
    "dbrx-132b": "dbrx_132b",
    "arctic-480b": "arctic_480b",
    "xlstm-1.3b": "xlstm_1_3b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "smollm-135m": "smollm_135m",
    "qwen3-32b": "qwen3_32b",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen3-14b": "qwen3_14b",
    "musicgen-medium": "musicgen_medium",
}


def list_archs() -> Tuple[str, ...]:
    return tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def iter_cells() -> Iterator[Tuple[ModelConfig, ShapeConfig, bool]]:
    """All 40 (arch x shape) cells; third element = supported (False => skip)."""
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            yield cfg, shape, shape_supported(cfg, shape)
