"""Batched experiment engine: an entire workload × scenario × rate × seed
sweep grid as ONE compiled JAX program per protocol.

The paper's headline results (Figs. 6–9) are sweeps over arrival rate,
protocol, and network scenario — and, beyond the paper, over *traffic
shape* (``repro.workloads``). Instead of re-tracing the tick-level
``jax.lax.scan`` for every grid point, ``run_sweep`` lowers a ``SweepSpec``
to a single ``jax.vmap``-over-scan dispatch:

  1. the channel delay horizon is resolved ONCE for the whole sweep
     (``netsim.resolve_horizon`` over every scenario in the grid) so all
     points share one ring shape — the packed channel rings are then
     exactly as large as the sweep's true delay bound;
  2. every scenario variant becomes an array-native env
     (``netsim.build_env`` with a common window-table pad), stacked
     leaf-wise — and every workload variant becomes a windowed rate table
     (``workloads.lower``, same pad-and-stack trick);
  3. the cartesian grid is flattened to B points, each an
     (env, workload-table, rate, seed) tuple gathered from the stacks;
  4. ``harness.sim_point`` — scan *plus* on-device metric extraction — is
     vmapped over the B axis and jitted once per
     (protocol, cfg, workload-mode, B) shape.

The analytic baselines (epaxos / rabia) have no tick loop; they are looped
on the host behind the same API (time-varying rates come from the same
compiled tables via ``workloads.analytic``) so callers can sweep any
protocol.

**Canonical program signatures.** Tracing + XLA-compiling a sweep program
dominates total wall-clock (BENCH_core.json: >=95% of every fig suite), so
``run_sweep`` canonicalizes program *shapes* by default: the
scenario/workload window tables round up to power-of-two floors, the
auto-resolved ring horizon to ``netsim.CANONICAL_HORIZON``, and the
program's batch width pins to ``CANONICAL_LANES`` (one lane) with the
grid executed as per-point async dispatches of that one program. Every
sweep with the same replica count, tick count, ring horizon, and
workload mode — the fig 6/7/9 suites, the robustness and workload
matrices, every ``run_sim`` single point — therefore reuses ONE compiled
program per protocol instead of compiling per-suite shape variants.
Canonicalization is inert by construction (vmap lanes are independent,
pad window rows are never indexed, a larger ring never clips a valid
delivery), and tests/test_scenarios.py pins canonical == native bitwise.
Pass ``canonical=False`` to lower and dispatch the whole grid at its
native width.

**Compile accounting.** ``trace_counts()`` exposes how many times each
protocol's program was traced — the equivalence tests
(tests/test_experiment.py, tests/test_workloads.py) pin a whole grid to
one trace — ``program_signatures()`` the distinct compiled signatures per
protocol (tests/test_compile_cache.py pins figs 6/7/9 to one), and
``timing_stats()`` the compile-vs-run wall-clock split plus the resolved
ring horizon. ``compile_report()`` joins all of that with the
persistent-cache counters (``repro.core.compile_cache``), which
benchmarks/run.py persists per suite to BENCH_core.json. Every sweep also
``compile_cache.ensure()``s the persistent cache, so repeat processes pay
XLA compile once ever.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import workloads as wlc
from repro.configs.smr import SMRConfig
from repro.core import compile_cache, harness, netsim
from repro.distributed import mesh as dmesh

ANALYTIC_PROTOCOLS = ("epaxos", "rabia")

# Canonical program width: ONE lane. A canonical sweep executes its grid
# as per-point dispatches of a single-lane compiled program, so a 1-point
# run_sim, a 4-rate fig sweep, and a 16-cell robustness matrix all share
# the same executable with zero padded (wasted) device work — padding the
# batch axis instead was measured at up to 4x execution wall on
# single-point sweeps. Window rows DO pad (rows are cheap: they are never
# indexed past the real count) to a power-of-two floor so a baseline
# (W=1) and a crash schedule (W=3) share one program.
CANONICAL_LANES = 1
# Window-table floor of 32 rows covers every library scenario and workload
# at both --quick (2s) and full (4s) sim lengths (gray-wan tops out at 30
# windows at 4s), so the fig suites AND the robustness matrix lower to the
# same scenario-window axis — one compiled program instead of a per-suite
# shape split (the robustness suite previously missed the cache on a
# 16-row variant).
CANONICAL_MIN_WINDOWS = 32

_TRACE_COUNTS: Dict[str, int] = {}
_TIMING: Dict[str, Dict[str, float]] = {}
_SIGNATURES: Dict[str, set] = {}


@dataclass(frozen=True, order=True)
class ProgramSignature:
    """The static shape key of one compiled sweep program. Two sweeps with
    equal signatures (and equal protocol / cfg statics / workload mode)
    hit the same jit cache entry — zero new traces, zero new compiles."""
    n: int             # replicas
    ticks: int         # scan length (sim_seconds / tick_ms)
    lanes: int         # compiled batch width (CANONICAL_LANES | grid size)
    scen_windows: int  # scenario window-table rows (padded)
    wl_windows: int    # workload window-table rows (padded)
    horizon: int       # channel-ring slots (Dmax)
    trivial: bool      # workload-mode statics
    closed: bool


def _canon_pow2(x: int, floor: int) -> int:
    """Next power of two >= x, floored at ``floor``."""
    return max(floor, 1 << (max(1, x) - 1).bit_length())


def trace_counts() -> Dict[str, int]:
    """jit traces of the sweep program per protocol since the last reset."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    """Reset the per-protocol trace counters and signature sets (the jit
    cache itself is untouched — a reused program still counts 0 traces)."""
    _TRACE_COUNTS.clear()
    _SIGNATURES.clear()
    _SHARD_SIGNATURES.clear()


def program_signatures() -> Dict[str, tuple]:
    """Distinct ``ProgramSignature``s lowered per protocol since the last
    ``reset_trace_counts()`` — the test oracle for "these suites share one
    compiled program"."""
    return {p: tuple(sorted(s)) for p, s in _SIGNATURES.items()}


def compile_report() -> Dict:
    """First-class compile accounting: per-protocol traces and distinct
    program signatures (since the last reset) plus the process-wide
    persistent-cache counters (hits/misses, backend-compile seconds,
    compile seconds saved). benchmarks/run.py snapshots this per suite
    into BENCH_core.json."""
    return {
        "traces": trace_counts(),
        "programs": {p: len(s) for p, s in _SIGNATURES.items()},
        "signatures": program_signatures(),
        "cache": compile_cache.stats(),
    }


def timing_stats() -> Dict[str, Dict[str, float]]:
    """Per-protocol wall-clock of the sweep dispatches since the last
    reset: ``compile_s`` (dispatches that traced: trace + lower + backend
    compile or persistent-cache load — execution is excluded because
    dispatch is async), ``run_s`` (cache-hit dispatch overhead plus every
    ``collect()``'s execution + readback wall), ``dispatches``, and
    ``horizon`` (the resolved ring size of the latest sweep)."""
    return {k: dict(v) for k, v in _TIMING.items()}


def reset_timing_stats() -> None:
    _TIMING.clear()


@dataclass(frozen=True)
class SweepSpec:
    """A sweep grid: cartesian product of rates (tx/s), PRNG seeds,
    network-scenario variants, and traffic-shape variants. Each entry of
    ``scenarios`` is a ``repro.scenarios.Scenario`` (None = fault-free
    baseline); each entry of ``workloads`` is a ``repro.workloads.Workload``
    (None = the §5.2 open-loop Poisson baseline). ``points()`` yields the
    flattened grid in rate-major order as (rate, seed, scenario_index,
    workload_index) — the same order ``run_sweep`` returns results in."""
    rates: Tuple[float, ...]
    seeds: Tuple[int, ...] = (0,)
    scenarios: Tuple = (None,)
    workloads: Tuple = (None,)

    def points(self) -> Iterator[Tuple[float, int, int, int]]:
        for rate, seed, fi, wi in itertools.product(
                self.rates, self.seeds, range(len(self.scenarios)),
                range(len(self.workloads))):
            yield float(rate), int(seed), fi, wi

    @property
    def size(self) -> int:
        return (len(self.rates) * len(self.seeds) * len(self.scenarios)
                * len(self.workloads))


def _sweep_body(protocol: str, cfg: SMRConfig, mode: wlc.WorkloadMode,
                env_b: Dict, wl_b: Dict, rate_b: jax.Array,
                seed_b: jax.Array) -> Dict:
    # body executes only while tracing, so this counts program builds
    _TRACE_COUNTS[protocol] = _TRACE_COUNTS.get(protocol, 0) + 1
    return jax.vmap(lambda env, wlt, rate, seed: harness.sim_point(
        protocol, cfg, env, rate, seed, wlt, mode))(
        env_b, wl_b, rate_b, seed_b)


_sweep_compiled = partial(
    jax.jit, static_argnames=("protocol", "cfg", "mode"))(_sweep_body)

# materialized canonical programs by key: in-memory second level of the
# program store (the disk level lives in compile_cache.program_dir())
_PROGRAMS: Dict[str, "jax.stages.Wrapped"] = {}


def _program_key(protocol: str, cfg: SMRConfig, mode: wlc.WorkloadMode,
                 args: tuple) -> str:
    """Disk key of one canonical program: everything that shapes the
    traced computation (protocol + cfg + workload-mode statics, the arg
    pytree structure with shapes/dtypes) plus the source fingerprint —
    editing any simulator source invalidates every stored program."""
    import hashlib
    leaves, treedef = jax.tree.flatten(args)
    parts = [protocol, repr(cfg), repr(mode),
             compile_cache.source_fingerprint(), str(treedef)]
    parts += [f"{np.asarray(x).dtype}{np.asarray(x).shape}" for x in leaves]
    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:32]


def _acquire_program(protocol: str, cfg: SMRConfig, mode: wlc.WorkloadMode,
                     args: tuple):
    """Return the callable for the canonical sweep program, building it at
    most once ever: in-memory first, then the on-disk program store (a
    ``jax.export`` blob — loading skips tracing AND lowering), and only
    as a last resort a fresh trace (which is then serialized for every
    future process). The XLA executable underneath is covered separately
    by the persistent compilation cache."""
    key = _program_key(protocol, cfg, mode, args)
    fn = _PROGRAMS.get(key)
    if fn is not None:
        return fn
    from jax import export as jax_export
    d = compile_cache.program_dir()
    path = d / f"{protocol}-{key}.bin" if d is not None else None
    exp = None
    if path is not None and path.exists():
        try:
            exp = jax_export.deserialize(path.read_bytes())
            # a loaded program counts as materialized, exactly like a
            # fresh trace would — per-process accounting stays identical
            # whether the store was warm or cold
            _TRACE_COUNTS[protocol] = _TRACE_COUNTS.get(protocol, 0) + 1
        except Exception:
            exp = None
    if exp is None:
        f = jax.jit(partial(_sweep_body, protocol, cfg, mode))
        exp = jax_export.export(f)(*args)  # traces once (body counts it)
        if path is not None:
            try:
                path.write_bytes(exp.serialize())
            except OSError:
                pass
    fn = jax.jit(exp.call)
    _PROGRAMS[key] = fn
    return fn


# mesh-sharded sweep programs, memoized per (protocol, statics, mesh):
# shard_map closures are fresh objects per call, so without this cache
# every dispatch would re-trace
_SHARDED: Dict[tuple, "jax.stages.Wrapped"] = {}
_SHARD_SIGNATURES: Dict[str, set] = {}


def shard_signatures() -> Dict[str, tuple]:
    """Distinct (ProgramSignature, devices) pairs dispatched through the
    sharded path per protocol since the last ``reset_trace_counts()``."""
    return {p: tuple(sorted(s)) for p, s in _SHARD_SIGNATURES.items()}


def _acquire_sharded(protocol: str, cfg: SMRConfig, mode: wlc.WorkloadMode,
                     mesh: "jax.sharding.Mesh"):
    """The mesh-sharded sweep program: the padded grid's leading axis is
    sharded over the 1-D ``("grid",)`` mesh and each device runs a
    ``jax.lax.map`` of the SAME single-lane point computation the
    canonical per-point path vmaps (``harness.sim_point`` with
    ``reduced=True``) — so per-point results are bitwise identical to the
    legacy dispatch loop while metrics reduce to O(sketch) bytes per
    point ON DEVICE before any host transfer. Tracing is counted in
    ``_TRACE_COUNTS`` like every other sweep program (the body runs only
    at trace time)."""
    key = (protocol, repr(cfg), repr(mode),
           tuple(d.id for d in mesh.devices.flat))
    fn = _SHARDED.get(key)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    def body(env_b, wl_b, rate_b, seed_b):
        _TRACE_COUNTS[protocol] = _TRACE_COUNTS.get(protocol, 0) + 1

        def one(point):
            env, wlt, rate, seed = point
            # one canonical lane per point: lift to the [1]-wide batch the
            # canonical program uses, then strip the lane axis
            out = jax.vmap(lambda e, w, r, s: harness.sim_point(
                protocol, cfg, e, r, s, w, mode, reduced=True))(
                jax.tree.map(lambda x: x[None], env),
                jax.tree.map(lambda x: x[None], wlt),
                rate[None], seed[None])
            return jax.tree.map(lambda x: x[0], out)

        return jax.lax.map(one, (env_b, wl_b, rate_b, seed_b))

    spec = PartitionSpec(dmesh.GRID_AXIS)
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec,
                           check_rep=False))
    _SHARDED[key] = fn
    return fn


def _lower(cfg: SMRConfig, spec: SweepSpec, canonical: bool = True):
    """Flatten the grid to stacked per-point inputs (env leaves, workload
    table leaves, rate, seed) plus the static workload mode and the
    horizon-resolved cfg (one ring shape for the whole grid). With
    ``canonical`` (the default), the shape axes are rounded to the
    canonical program signature: window tables pad to a power-of-two
    floor (pad rows are never indexed — ``win_of_tick`` only addresses
    real windows), the auto horizon rounds up to
    ``netsim.CANONICAL_HORIZON``, and the program width is pinned to
    ``CANONICAL_LANES`` — the grid then executes as per-point dispatches
    of that one program (lanes are independent under vmap, so chunked
    execution is bitwise identical to one wide dispatch; pinned in
    tests)."""
    from repro import scenarios as sc
    pts = list(spec.points())
    # lower every scenario ONCE: the tables feed both the sweep-wide
    # horizon resolution and the padded env stack. build_env gets the
    # ORIGINAL cfg (envs don't embed the horizon), so its static-delay
    # validation sees the user's auto-vs-pinned intent exactly as a
    # direct build_env call would; only the compiled program takes the
    # sweep-wide resolved horizon.
    stabs = [sc.lower(cfg, sc.as_scenario(f)) for f in spec.scenarios]
    n_windows = max(t["alive"].shape[0] for t in stabs)
    wl_pad = max(wlc.compile.n_windows(cfg, w) for w in spec.workloads)
    lanes = len(pts)
    if canonical:
        n_windows = _canon_pow2(n_windows, CANONICAL_MIN_WINDOWS)
        wl_pad = _canon_pow2(wl_pad, CANONICAL_MIN_WINDOWS)
        lanes = CANONICAL_LANES
    # stack host-side (numpy), not netsim.stack_envs (device): the lane
    # gather below and the per-chunk slices in dispatch_sweep then cost
    # nothing instead of compiling one gather program per leaf shape
    stack = jax.tree.map(
        lambda *xs: np.stack([np.asarray(x) for x in xs]),
        *[netsim.build_env(cfg, f, n_windows, tab=t)
          for f, t in zip(spec.scenarios, stabs)])
    cfg = netsim.resolve_horizon(cfg, tabs=stabs, canonical=canonical)
    # the stacks always hold every real point; ``lanes`` is the width of
    # the compiled program (dispatch_sweep chunks the grid to fit)
    lane_pts = pts
    fidx = np.array([fi for _, _, fi, _ in lane_pts], np.int32)
    env_b = jax.tree.map(lambda x: x[fidx], stack)
    # the static workload mode is judged on the UNPADDED lowerings —
    # canonical window padding must not kick a trivial (all-ones
    # single-window) grid off the seed-identical fast path
    mode = wlc.mode_of([wlc.lower(cfg, w) for w in spec.workloads])
    tabs = [wlc.lower(cfg, w, pad_windows=wl_pad) for w in spec.workloads]
    widx = np.array([wi for _, _, _, wi in lane_pts], np.int32)
    # win_start is host-side metadata (ragged across workloads); only the
    # fixed-shape device tables ride into the compiled program. All lane
    # stacks stay host-side numpy so per-chunk slicing is free (device
    # slicing would compile one gather program per leaf shape)
    dev = [{k: v for k, v in t.items() if k != "win_start"} for t in tabs]
    wl_b = jax.tree.map(lambda *xs: np.stack(xs)[widx], *dev)
    # per-replica Poisson rate per tick, computed host-side in float64 so a
    # batched grid and a single run_sim see bit-identical inputs
    # lint: allow(dtype-hygiene): deliberate f64 host math for grid /
    # single-run bit-exactness; .astype(np.float32) before the device
    rate_b = (np.array([r for r, _, _, _ in lane_pts], np.float64)
              * cfg.tick_ms / 1000.0 / cfg.n_replicas).astype(np.float32)
    seed_b = np.array([s for _, s, _, _ in lane_pts], np.int32)
    sig = ProgramSignature(
        n=cfg.n_replicas, ticks=netsim.sim_ticks(cfg), lanes=lanes,
        scen_windows=n_windows, wl_windows=wl_pad,
        horizon=int(cfg.delay_horizon_ticks),
        trivial=mode.trivial, closed=mode.closed)
    return pts, cfg, mode, env_b, wl_b, rate_b, seed_b, sig


class PendingSweep:
    """A dispatched sweep whose device computation may still be running.
    ``collect()`` blocks on the results and materializes the per-point
    dicts. Dispatching several sweeps before collecting any (see
    ``run_sweeps``) overlaps each program's device execution with the
    next program's trace/lowering — on a warm persistent cache that
    overlap is most of a fig suite's wall-clock."""

    def __init__(self, protocol: str, *, results: List[Dict] = None,
                 pts=None, wl_names=None, outs=None, n_real=None):
        self.protocol = protocol
        self._results = results   # analytic protocols resolve eagerly
        self._pts = pts
        self._wl_names = wl_names
        self._outs = outs         # async device-array trees, one per chunk
        self._n_real = n_real     # sharded path: real points before padding

    def collect(self) -> List[Dict]:
        if self._results is not None:
            return self._results
        t0 = time.perf_counter()
        chunks = [jax.tree.map(np.asarray, o) for o in self._outs]
        # tree-aware concat: with tracing on, sim_point outputs carry
        # nested subtrees (per-layer obs rings), not just flat arrays
        out = (chunks[0] if len(chunks) == 1 else
               jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *chunks))
        if self._n_real is not None:
            # sharded decode: drop the rows that padded the grid to a
            # multiple of the mesh size (repeats of the last real point)
            out = jax.tree.map(lambda x: x[:self._n_real], out)
        stats = _TIMING[self.protocol]
        stats["run_s"] += time.perf_counter() - t0
        self._outs = None
        results: List[Dict] = []
        for i, (rate, seed, fi, wi) in enumerate(self._pts):
            r: Dict = {"protocol": self.protocol, "rate": rate,
                       "seed": seed,
                       "workload": self._wl_names[wi],
                       "throughput": float(out["throughput"][i]),
                       "median_ms": float(out["median_ms"][i]),
                       "p99_ms": float(out["p99_ms"][i]),
                       "committed": float(out["committed"][i])}
            # per-batch/per-tick arrays: present on the legacy path,
            # replaced by the fixed-size sketch on the reduced path
            for k in ("timeline", "origin_median_ms", "origin_p99_ms",
                      "origin_timeline", "origin_lat_ms_timeline"):
                if k in out:
                    r[k] = out[k][i]
            if self.protocol == "mandator-sporades":
                r["async_frac"] = float(out["async_frac"][i])
                r["views"] = int(out["views"][i])
                if "cvc_all" in out:
                    r["cvc_all"] = out["cvc_all"][i]
                if "commit_key" in out:
                    r["commit_key"] = out["commit_key"][i]
            if "inflight_max" in out:
                r["inflight_max"] = out["inflight_max"][i]
            # flight-recorder outputs (absent at TraceLevel.OFF, so the
            # default result schema is untouched)
            for k in ("phase_med_ms", "phase_p99_ms", "phase_origin_med_ms",
                      "phase_origin_p99_ms", "batch_marks_t", "batch_arr_t",
                      "batch_n"):
                if k in out:
                    r[k] = out[k][i]
            if "sketch" in out:
                r["sketch"] = {"v": out["sketch"]["v"][i],
                               "w": out["sketch"]["w"][i]}
            if "obs" in out:
                r["obs"] = jax.tree.map(lambda x: x[i], out["obs"])
            # health-monitor outputs (absent at MonitorLevel.OFF)
            if "mon" in out:
                r["mon"] = jax.tree.map(lambda x: x[i], out["mon"])
            results.append(r)
        self._results = results
        return results


def dispatch_sweep(protocol: str, cfg: SMRConfig, spec: SweepSpec,
                   canonical: bool = True, mesh=None) -> PendingSweep:
    """Lower + dispatch the grid without blocking on the device
    computation. ``canonical`` pads the program to the canonical
    signature (see ``_lower``) so shape-compatible sweeps share one
    compiled program. Analytic baselines (host loops) resolve eagerly.

    ``mesh`` selects the mesh-sharded engine: None (default) keeps the
    legacy per-point dispatch loop; an int or a ``jax.sharding.Mesh``
    with a ``("grid",)`` axis (see ``repro.distributed.mesh``) shards the
    flattened grid's leading axis over the mesh devices as ONE dispatch,
    each device scanning its grid slice with the same canonical
    single-lane point program and reducing metrics on device to a
    fixed-size latency sketch (``harness.sim_point(reduced=True)``).
    Analytic protocols ignore ``mesh`` (host loops have no device
    program)."""
    wl_names = [wlc.as_workload(w).name for w in spec.workloads]
    if protocol in ANALYTIC_PROTOCOLS:
        if protocol == "epaxos":
            from repro.core.epaxos import run_epaxos_model as model
        else:
            from repro.core.rabia import run_rabia_model as model
        out = []
        for rate, seed, fi, wi in spec.points():
            r = model(cfg, rate, spec.scenarios[fi],
                      workload=spec.workloads[wi])
            r["seed"] = seed
            r["workload"] = wl_names[wi]
            out.append(r)
        return PendingSweep(protocol, results=out)
    if protocol not in harness.SCAN_PROTOCOLS:
        raise ValueError(protocol)

    compile_cache.ensure()
    mesh = dmesh.as_grid_mesh(mesh)
    pts, cfg, mode, env_b, wl_b, rate_b, seed_b, sig = _lower(
        cfg, spec, canonical=canonical)
    # the sharded path registers the SAME canonical signature — the point
    # computation (and so the persistent-cache key material) is unchanged;
    # only the orchestration around it is
    _SIGNATURES.setdefault(protocol, set()).add(sig)
    traces_before = _TRACE_COUNTS.get(protocol, 0)
    if mesh is not None:
        n_dev = int(mesh.devices.size)
        _SHARD_SIGNATURES.setdefault(protocol, set()).add((sig, n_dev))
        pad = (-len(pts)) % n_dev
        if pad:
            # pad the grid to a multiple of the mesh size by repeating the
            # last real point; collect() slices the repeats back off
            idx = np.concatenate([np.arange(len(pts)),
                                  np.full(pad, len(pts) - 1)]).astype(np.int64)
            env_b = jax.tree.map(lambda x: x[idx], env_b)
            wl_b = jax.tree.map(lambda x: x[idx], wl_b)
            rate_b, seed_b = rate_b[idx], seed_b[idx]
        fn = _acquire_sharded(protocol, cfg, mode, mesh)
        t0 = time.perf_counter()
        outs = [fn(env_b, wl_b, rate_b, seed_b)]
        dt = time.perf_counter() - t0
        stats = _TIMING.setdefault(protocol, {
            "compile_s": 0.0, "run_s": 0.0, "dispatches": 0, "horizon": 0})
        bucket = ("compile_s"
                  if _TRACE_COUNTS.get(protocol, 0) > traces_before
                  else "run_s")
        stats[bucket] += dt
        stats["dispatches"] += 1
        stats["horizon"] = int(cfg.delay_horizon_ticks)
        return PendingSweep(protocol, pts=pts, wl_names=wl_names, outs=outs,
                            n_real=len(pts))
    t0 = time.perf_counter()
    if sig.lanes == len(pts):
        chunks = [(env_b, wl_b, rate_b, seed_b)]
    else:
        # canonical: the grid runs as per-point async dispatches of the
        # shared ``CANONICAL_LANES``-wide program (lanes are independent
        # under vmap, so this is bitwise identical to one wide dispatch)
        chunks = [(jax.tree.map(lambda x: x[i:i + 1], env_b),
                   jax.tree.map(lambda x: x[i:i + 1], wl_b),
                   rate_b[i:i + 1], seed_b[i:i + 1])
                  for i in range(len(pts))]
    fn = _sweep_compiled
    if canonical:
        # canonical programs additionally go through the on-disk program
        # store: warm processes deserialize the traced computation instead
        # of re-tracing it (the persistent XLA cache below then supplies
        # the executable)
        try:
            prog = _acquire_program(protocol, cfg, mode, chunks[0])
            fn = lambda _p, _c, _m, *a: prog(*a)  # noqa: E731
        except Exception:
            fn = _sweep_compiled  # fall back to plain jit
    outs = [fn(protocol, cfg, mode, *c) for c in chunks]
    dt = time.perf_counter() - t0
    stats = _TIMING.setdefault(protocol, {
        "compile_s": 0.0, "run_s": 0.0, "dispatches": 0, "horizon": 0})
    # dispatch returns before the device finishes: this bucket is pure
    # trace + lower + (backend compile | cache load); collect() adds the
    # execution + readback wall to run_s
    bucket = ("compile_s" if _TRACE_COUNTS.get(protocol, 0) > traces_before
              else "run_s")
    stats[bucket] += dt
    stats["dispatches"] += 1
    stats["horizon"] = int(cfg.delay_horizon_ticks)
    return PendingSweep(protocol, pts=pts, wl_names=wl_names, outs=outs)


def run_sweep(protocol: str, cfg: SMRConfig, spec: SweepSpec,
              canonical: bool = True, mesh=None) -> List[Dict]:
    """Run the whole grid; returns one result dict per point, in
    ``spec.points()`` order. Scan protocols execute as a single vmapped
    device dispatch; analytic baselines loop on the host. ``mesh``
    selects the mesh-sharded engine (see ``dispatch_sweep``)."""
    return dispatch_sweep(protocol, cfg, spec, canonical=canonical,
                          mesh=mesh).collect()


def run_sweeps(requests) -> List[List[Dict]]:
    """Dispatch every (protocol, cfg, spec) request before collecting any,
    so device execution overlaps host-side tracing/lowering of the later
    programs. Returns per-request result lists in request order —
    identical to ``[run_sweep(*r) for r in requests]``, just faster."""
    pending = [dispatch_sweep(p, cfg, spec) for p, cfg, spec in requests]
    return [p.collect() for p in pending]
