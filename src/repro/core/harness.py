"""SMR simulation harness: drives a protocol over the WAN sim and produces
the paper's metrics (throughput, median/p99 execution latency, timelines).

Protocols:
  mandator-sporades  — Alg 1 + Algs 2/3 (full tick-level state machines)
  mandator-paxos     — Alg 1 + Multi-Paxos ordering the vector clock
  multipaxos         — monolithic Multi-Paxos (batches inside consensus)
  mandator           — dissemination layer alone (completion throughput)
  epaxos / rabia     — analytic baselines (see docstrings in epaxos.py/rabia.py)

Everything here is traceable end-to-end: ``sim_point`` runs the tick-level
``jax.lax.scan`` AND extracts the metrics on-device (searchsorted commit
reconstruction, weighted quantiles, timeline histogram), so the batched
experiment engine (core/experiment.py) can ``jax.vmap`` a whole
rate × seed × fault grid into one compiled program. ``run_sim`` is a thin
single-point wrapper over that engine, kept for backward compatibility.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.smr import SMRConfig
from repro.core import channel as ch
from repro.core import mandator, netsim, paxos, sporades
from repro.distributed import sketch as dsketch
from repro.obs import monitor as hmon
from repro.obs import trace as obs
from repro.workloads.compile import TRIVIAL_MODE, WorkloadMode

SCAN_PROTOCOLS = ("mandator-sporades", "mandator-paxos", "multipaxos",
                  "mandator")


def _closed_feedback(protocol: str, carry: Dict, out: Dict) -> Dict:
    """Closed-loop commit feedback, inside the scan carry: a request is in
    flight from Poisson submission until the batch carrying it commits.
    ``cl_done`` is the cumulative per-origin committed request count,
    recovered from the batch records and the protocol's committed rounds
    (both monotone, so no per-round event bookkeeping is needed)."""
    wl_key = "p" if protocol == "multipaxos" else "m"
    carry = dict(carry)
    carry[wl_key] = dict(carry[wl_key])
    wl = dict(carry[wl_key]["wl"])
    if protocol == "mandator":
        cvc_o = carry["m"]["own_round"]
    elif protocol == "mandator-sporades":
        cvc_o = jnp.max(carry["s"]["cvc"], axis=0)
    elif protocol == "mandator-paxos":
        cvc_o = jnp.max(carry["p"]["cvc"], axis=0)
    else:
        cvc_o = carry["p"]["committed_slot"]
    # cumulative committed count = the prefix sum at the committed round
    # (rounds are formed and committed in order per row)
    r_max = wl["batch_count_cum"].shape[1]
    n = cvc_o.shape[0]
    done = wl["batch_count_cum"][jnp.arange(n),
                                 jnp.clip(cvc_o, 0, r_max - 1)]
    if protocol == "multipaxos":
        # batch rows live at the (rotating) leader, not the submitting
        # origin, so per-origin completion is unknowable: apportion the
        # global committed total (monotone) pro-rata by cumulative
        # submissions. The aggregate is exact; the per-origin split is an
        # estimate that may move as shares shift, so no per-origin
        # ratchet — a maximum here would overcount done and silently
        # admit requests past the cap. (Requests forwarded to a dead
        # leader stay in flight; client retry is not modeled, DESIGN.md §8.)
        share = wl["cl_submitted"] / jnp.maximum(
            jnp.sum(wl["cl_submitted"]), 1.0)
        done = jnp.sum(done) * share
        wl["cl_done"] = jnp.clip(done, 0.0, wl["cl_submitted"])
    else:
        wl["cl_done"] = jnp.clip(jnp.maximum(wl["cl_done"], done),
                                 0.0, wl["cl_submitted"])
    carry[wl_key]["wl"] = wl
    out["inflight"] = wl["cl_submitted"] - wl["cl_done"]
    return carry


def _monitor_views(protocol: str, cfg: SMRConfig, carry: Dict) -> Dict:
    """Protocol-state projection the health monitor consumes
    (repro.obs.monitor.update): per-replica committed vector clocks /
    monotone commit keys / views where the protocol has them (None keys
    statically compile the corresponding check out), per-origin formed vs
    stable rounds (the starvation gauge), a cluster commit total (the
    watchdog's progress signal), a pending-work flag, packed-ring
    occupancy, and the per-tick dropped-send counts the ticks stash in
    ``mon_io``."""
    n = cfg.n_replicas
    views: Dict = {"cvc": None, "commit_seq": None, "view": None}
    rings = []
    dropped = jnp.zeros((n,), jnp.int32)
    if protocol in ("mandator-sporades", "mandator-paxos", "mandator"):
        m = carry["m"]
        rings.append((mandator.ring_spec(), m["ring"]))
        dropped = dropped + m["mon_io"]["dropped"]
        views["formed"] = m["formed_round"]
        views["stable"] = m["own_round"]
        pending = jnp.sum(m["wl"]["buffer"]) > 0
    if protocol == "mandator":
        # lcr rows are per-replica *knowledge* vectors — cross-replica
        # comparability is not an invariant of dissemination alone, so no
        # cvc here (no agreement check); completion order still is one.
        views["commit_seq"] = m["own_round"]
        views["commit_tot"] = jnp.sum(m["own_round"]).astype(jnp.float32)
        views["pending"] = pending | jnp.any(
            m["formed_round"] > m["own_round"])
    elif protocol == "mandator-sporades":
        s = carry["s"]
        rings.append((sporades.ring_spec(n), s["ring"]))
        dropped = dropped + s["mon_io"]["dropped"]
        views["cvc"] = s["cvc"]
        views["commit_seq"] = s["commit_key"]
        views["view"] = s["v_cur"]
        views["commit_tot"] = jnp.sum(s["cvc"]).astype(jnp.float32)
        views["pending"] = pending | jnp.any(
            m["formed_round"] > jnp.max(s["cvc"], axis=0))
    elif protocol == "mandator-paxos":
        p = carry["p"]
        rings.append((paxos.ring_spec(n, True), p["ring"]))
        dropped = dropped + p["mon_io"]["dropped"]
        views["cvc"] = p["cvc"]
        views["view"] = p["view"]
        views["commit_tot"] = jnp.sum(p["cvc"]).astype(jnp.float32)
        views["pending"] = pending | jnp.any(
            m["formed_round"] > jnp.max(p["cvc"], axis=0))
    elif protocol == "multipaxos":
        p = carry["p"]
        rings.append((paxos.ring_spec(n, False), p["ring"]))
        dropped = dropped + p["mon_io"]["dropped"]
        # per-replica slot counters are each leader's own ledger: formed
        # (last started) vs stable (last committed) per replica
        views["formed"] = p["slot"]
        views["stable"] = p["committed_slot"]
        views["commit_seq"] = p["committed_slot"]
        views["view"] = p["view"]
        views["commit_tot"] = jnp.sum(
            p["committed_slot"]).astype(jnp.float32)
        views["pending"] = (jnp.sum(p["wl"]["buffer"]) > 0) \
            | jnp.any(p["outstanding"])
    occ = [ch.ring_occupancy(spec, ring) for spec, ring in rings]
    views["ring_occ"] = occ[0] if len(occ) == 1 else jnp.maximum(*occ)
    views["dropped"] = dropped
    return views


def _scan_body(protocol: str, cfg: SMRConfig, n_ticks: int,
               rate_per_tick: jax.Array, env: Dict, seed: jax.Array,
               wlt: Dict | None = None,
               mode: WorkloadMode = TRIVIAL_MODE):
    """The tick loop. protocol/cfg/n_ticks/mode are static; rate_per_tick,
    env and wlt leaves, and seed may be traced (and batched by vmap)."""
    uses_mandator = protocol in ("mandator-sporades", "mandator-paxos",
                                 "mandator")
    st = {}
    if uses_mandator:
        st["m"] = mandator.init_state(cfg, n_ticks, closed=mode.closed)
    if protocol == "mandator-sporades":
        st["s"] = sporades.init_state(cfg, n_ticks)
    if protocol in ("mandator-paxos", "multipaxos"):
        st["p"] = paxos.init_state(cfg, n_ticks,
                                   mandator_mode=(protocol == "mandator-paxos"),
                                   closed=mode.closed)
    # health monitor (repro.obs.monitor): absent from the carry at the
    # default monitor_level="off" — the compiled program is then
    # instruction-identical to an unmonitored build, like trace_level
    mon_on = hmon.on(cfg.monitor_level)
    if mon_on:
        st["mon"] = hmon.init_monitor(cfg, n_ticks,
                                      _monitor_views(protocol, cfg, st))
        grace = hmon.stall_grace_ticks(cfg, env)
    base_key = jax.random.PRNGKey(seed)

    def step(carry, t):
        key = jax.random.fold_in(base_key, t)
        out = {}
        if uses_mandator:
            carry = dict(carry)
            carry["m"] = mandator.tick(carry["m"], t, key, env, cfg,
                                       rate_per_tick, wlt, mode)
            lcr = mandator.get_client_requests(carry["m"])
            out["own_round"] = carry["m"]["own_round"]
        if protocol == "mandator-sporades":
            carry["s"] = sporades.tick(carry["s"], t, env, cfg, lcr)
            out["cvc"] = jnp.max(carry["s"]["cvc"], axis=0)
            out["cvc_all"] = carry["s"]["cvc"]
            out["commit_key"] = carry["s"]["commit_key"]
            out["is_async"] = carry["s"]["is_async"]
            out["v_cur"] = carry["s"]["v_cur"]
        elif protocol == "mandator-paxos":
            carry["p"] = paxos.tick(carry["p"], t, key, env, cfg,
                                    rate_per_tick, True, lcr=lcr)
            out["cvc"] = jnp.max(carry["p"]["cvc"], axis=0)
            if cfg.trace_level != obs.TraceLevel.OFF:
                # each origin's OWN committed-VC observation — the
                # delivery-phase boundary (sporades reads it off the
                # cvc_all trace it already emits; off => compiled out)
                out["cvc_own"] = jnp.diagonal(carry["p"]["cvc"])
        elif protocol == "multipaxos":
            carry = dict(carry)
            carry["p"] = paxos.tick(carry["p"], t, key, env, cfg,
                                    rate_per_tick, False, wlt=wlt, mode=mode)
            out["committed_slot"] = carry["p"]["committed_slot"]
        if mode.closed:
            carry = _closed_feedback(protocol, carry, out)
        if mon_on:
            carry = dict(carry)
            carry["mon"] = hmon.update(
                carry["mon"], t, cfg, env,
                _monitor_views(protocol, cfg, carry), grace, wlt=wlt,
                inflight=out.get("inflight"),
                # multipaxos closed-loop completion is a pro-rata estimate
                # (see _closed_feedback), not an exact per-origin count —
                # the cap invariant is only checkable where done is exact
                check_cap=mode.closed and protocol != "multipaxos")
        return carry, out

    st, trace = jax.lax.scan(step, st, jnp.arange(n_ticks, dtype=jnp.int32))
    return st, trace


def _weighted_quantile(vals: jax.Array, weights: jax.Array, q: float
                       ) -> jax.Array:
    """On-device weighted quantile over flat arrays; zero-weight entries are
    inert (they only flatten the CDF) so no boolean filtering is needed."""
    order = jnp.argsort(vals)
    v, w = vals[order], weights[order]
    cum = jnp.cumsum(w)
    tot = cum[-1]
    # guard the denominator, not just the result: an empty window would
    # otherwise divide by zero before the where (trips jax_debug_nans)
    cdf = cum / jnp.where(tot > 0, tot, 1.0)
    idx = jnp.clip(jnp.searchsorted(cdf, q, side="left"),
                   0, v.shape[0] - 1)
    return jnp.where(tot > 0, v[idx], jnp.nan)


def _batch_metrics(cfg: SMRConfig, create_t, arr_mean, count, commit_t,
                   warmup_frac=0.15, bucket_ms=500.0) -> Dict:
    """Metrics over batch records [n, R] (ticks -> ms via cfg.tick_ms),
    fully on-device so it vmaps across grid points."""
    n_ticks = netsim.sim_ticks(cfg)
    ok = jnp.isfinite(commit_t) & (count > 0) & jnp.isfinite(create_t)
    lat_ms = (commit_t - arr_mean) * cfg.tick_ms
    w0 = warmup_frac * n_ticks
    in_win = ok & (commit_t >= w0)
    win_s = (n_ticks - w0) * cfg.tick_ms / 1000.0
    w = jnp.where(in_win, count, 0.0).ravel()
    tput = jnp.sum(w) / win_s if win_s > 0 else jnp.float32(0.0)
    med = _weighted_quantile(lat_ms.ravel(), w, 0.5)
    p99 = _weighted_quantile(lat_ms.ravel(), w, 0.99)
    nbuck = int(np.ceil(n_ticks * cfg.tick_ms / bucket_ms))
    b = jnp.where(ok, commit_t * (cfg.tick_ms / bucket_ms), 0.0
                  ).astype(jnp.int32).clip(0, nbuck - 1)
    cnt_ok = jnp.where(ok, count, 0.0)
    timeline = jnp.zeros((nbuck,)).at[b.ravel()].add(cnt_ok.ravel())
    timeline = timeline / (bucket_ms / 1000.0)
    # per-origin client-perceived latency: where is the latency paid?
    # (rows are submitting origins for the mandator-family protocols;
    # for multipaxos they are the leader that formed the slot batch)
    n = count.shape[0]
    w_o = jnp.where(in_win, count, 0.0)                       # [n, R]
    med_o = jax.vmap(lambda v, ww: _weighted_quantile(v, ww, 0.5))(
        lat_ms, w_o)
    p99_o = jax.vmap(lambda v, ww: _weighted_quantile(v, ww, 0.99))(
        lat_ms, w_o)
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], b.shape)
    tl_o = jnp.zeros((n, nbuck)).at[rows, b].add(cnt_ok)
    lat_sum = jnp.zeros((n, nbuck)).at[rows, b].add(
        cnt_ok * jnp.where(ok, lat_ms, 0.0))
    lat_tl_o = jnp.where(tl_o > 0, lat_sum / jnp.maximum(tl_o, 1e-9),
                         jnp.nan)
    return {"throughput": tput, "median_ms": med, "p99_ms": p99,
            "timeline": timeline,
            "committed": jnp.sum(cnt_ok),
            "origin_median_ms": med_o, "origin_p99_ms": p99_o,
            "origin_timeline": tl_o / (bucket_ms / 1000.0),
            "origin_lat_ms_timeline": lat_tl_o}


# Per-batch / per-tick output arrays whose size scales with the grid's
# record capacity — the ones the sharded sweep path (experiment.py) trades
# for the O(SKETCH_BINS) latency sketch so a 10^4-point grid returns
# O(sketch) bytes per point. Scalar metrics are untouched: ``reduced``
# mode computes them with the IDENTICAL op sequence (the heavy keys are
# simply not program outputs, so XLA dead-code-eliminates their compute).
REDUCED_DROPS = ("timeline", "origin_median_ms", "origin_p99_ms",
                 "origin_timeline", "origin_lat_ms_timeline",
                 "cvc_all", "commit_key",
                 "batch_marks_t", "batch_arr_t", "batch_n")


def _latency_sketch(cfg: SMRConfig, create_t, arr_mean, count, commit_t,
                    warmup_frac=0.15) -> Dict:
    """Fixed-size on-device digest of the committed-latency distribution,
    over the same measurement window / weights as ``_batch_metrics``
    (duplicated ops CSE away under jit)."""
    n_ticks = netsim.sim_ticks(cfg)
    ok = jnp.isfinite(commit_t) & (count > 0) & jnp.isfinite(create_t)
    lat_ms = (commit_t - arr_mean) * cfg.tick_ms
    in_win = ok & (commit_t >= warmup_frac * n_ticks)
    w = jnp.where(in_win, count, 0.0).ravel()
    # zero-weight rows may hold inf/nan latencies (uncommitted batches);
    # dsketch.build masks them instead of multiplying through
    return dsketch.build(lat_ms.ravel(), w)


def _vc_commit_ticks(cvc_trace: jax.Array, r_max: int) -> jax.Array:
    """cvc_trace: [ticks, n] monotone. Returns [n, r_max] where column r is
    the commit tick of batch (k, r); rounds are 1-based so column 0 is inf,
    and inf marks rounds that never commit."""
    ticks = cvc_trace.shape[0]
    rs = jnp.arange(r_max)

    def per_origin(col):
        idx = jnp.searchsorted(col, rs, side="left")
        valid = (idx < ticks) & (rs >= 1)
        return jnp.where(valid, idx.astype(jnp.float32), jnp.inf)

    return jax.vmap(per_origin, in_axes=1)(cvc_trace)


def sim_point(protocol: str, cfg: SMRConfig, env: Dict,
              rate_per_tick: jax.Array, seed: jax.Array,
              wlt: Dict | None = None,
              mode: WorkloadMode = TRIVIAL_MODE,
              reduced: bool = False) -> Dict:
    """One grid point, traceable end-to-end: tick scan + on-device metric
    extraction. Returns a dict of arrays (scalars unless noted). ``wlt``
    is the compiled workload table (ignored when mode.trivial); ``mode``
    is static and must match how wlt was compiled.

    ``reduced`` (static) is the sharded sweep engine's metric contract:
    scalar metrics keep the exact unreduced op sequence (bitwise-equal
    values), the per-batch/per-tick arrays in ``REDUCED_DROPS`` are
    omitted, and a fixed-size latency ``sketch`` is added in their place
    so each point returns O(SKETCH_BINS) bytes of distribution."""
    n_ticks = netsim.sim_ticks(cfg)
    st, trace = _scan_body(protocol, cfg, n_ticks, rate_per_tick, env, seed,
                           wlt, mode)
    if protocol == "mandator":
        # dissemination completion = "commit" for availability accounting
        wl, cvc = st["m"]["wl"], trace["own_round"]
    elif protocol in ("mandator-sporades", "mandator-paxos"):
        # batch r commits once the committed VC reaches r (1-based rounds)
        wl, cvc = st["m"]["wl"], trace["cvc"]
    elif protocol == "multipaxos":
        wl, cvc = st["p"]["wl"], trace["committed_slot"]
    else:
        raise ValueError(protocol)
    commit_t = _vc_commit_ticks(cvc, wl["batch_count"].shape[1])
    out = _batch_metrics(cfg, wl["batch_create_t"], wl["batch_arr_mean"],
                         wl["batch_count"], commit_t)
    if protocol == "mandator-sporades":
        out["async_frac"] = jnp.mean(trace["is_async"].astype(jnp.float32))
        out["views"] = jnp.max(trace["v_cur"])
        out["cvc_all"] = trace["cvc_all"]          # [ticks, n, n]
        out["commit_key"] = trace["commit_key"]    # [ticks, n]
    if mode.closed:
        out["inflight_max"] = jnp.max(trace["inflight"], axis=0)   # [n]
    if cfg.trace_level != obs.TraceLevel.OFF:
        out.update(_phase_breakdown(protocol, cfg, wl, trace, commit_t,
                                    n_ticks))
        rings = {layer: obs.public_view(st[k].get("tr"))
                 for k, layer in (("m", "mandator"), ("s", "sporades"),
                                  ("p", "paxos")) if k in st}
        out["obs"] = {k: v for k, v in rings.items() if v is not None}
    if hmon.on(cfg.monitor_level):
        out["mon"] = hmon.public_view(st["mon"], n_ticks)
    if reduced:
        out = {k: v for k, v in out.items() if k not in REDUCED_DROPS}
        out["sketch"] = _latency_sketch(
            cfg, wl["batch_create_t"], wl["batch_arr_mean"],
            wl["batch_count"], commit_t)
    return out


def _phase_breakdown(protocol: str, cfg: SMRConfig, wl: Dict, trace: Dict,
                     commit_t: jax.Array, n_ticks: int,
                     warmup_frac: float = 0.15) -> Dict:
    """Latency-breakdown accounting (repro.obs.PHASES): split each
    committed batch's end-to-end latency at three protocol boundaries —
    batch creation at the origin (queue | dissemination), stability
    (n-f dissemination votes; dissemination | consensus), and global
    commit (consensus | delivery, the origin's own observation). The
    four phase marks telescope back to the client-perceived latency of
    ``_batch_metrics`` exactly (± nothing: same arrival mean, same
    commit reconstruction), pinned by tests/test_obs.py."""
    r_max = wl["batch_count"].shape[1]
    create_t, arr_t = wl["batch_create_t"], wl["batch_arr_mean"]
    cnt = wl["batch_count"]
    if protocol == "mandator":
        # dissemination IS the protocol: completion == commit == delivery
        stable_t = deliv_t = commit_t
    elif protocol in ("mandator-sporades", "mandator-paxos"):
        # stability = the origin's own chain completing the round
        stable_t = _vc_commit_ticks(trace["own_round"], r_max)
        own_cvc = (jnp.diagonal(trace["cvc_all"], axis1=1, axis2=2)
                   if protocol == "mandator-sporades" else trace["cvc_own"])
        deliv_t = _vc_commit_ticks(own_cvc, r_max)
    else:  # multipaxos: monolithic — the slot batch enters consensus as
        # it forms, and commit is observed at the committing leader
        stable_t = create_t
        deliv_t = commit_t
    marks = jnp.stack([create_t, stable_t, commit_t, deliv_t])  # [4, n, R]
    prev = jnp.stack([arr_t, create_t, stable_t, commit_t])
    phases_ms = jnp.maximum(marks - prev, 0.0) * cfg.tick_ms
    ok = jnp.isfinite(marks).all(axis=0) & (cnt > 0)
    in_win = ok & (commit_t >= warmup_frac * n_ticks)   # same window as
    w = jnp.where(in_win, cnt, 0.0)                     # _batch_metrics
    glob = jax.vmap(lambda v, q: _weighted_quantile(v.ravel(), w.ravel(), q),
                    in_axes=(0, None))
    origin = jax.vmap(jax.vmap(_weighted_quantile, in_axes=(0, 0, None)),
                      in_axes=(0, None, None))
    out = {"phase_med_ms": glob(phases_ms, 0.5),             # [4]
           "phase_p99_ms": glob(phases_ms, 0.99),
           "phase_origin_med_ms": origin(phases_ms, w, 0.5),  # [4, n]
           "phase_origin_p99_ms": origin(phases_ms, w, 0.99)}
    if cfg.trace_level == obs.TraceLevel.FULL:
        out["batch_marks_t"] = marks      # absolute ticks, inf = never
        out["batch_arr_t"] = arr_t
        out["batch_n"] = cnt
    return out


def run_sim(protocol: str, cfg: SMRConfig, rate_tx_s: float,
            scenario=None, seed: int = 0, workload=None,
            canonical: bool = True) -> Dict:
    """Single-point wrapper over the batched engine (experiment.run_sweep).
    scenario: a repro.scenarios.Scenario (or None for fault-free).
    workload: a repro.workloads.Workload (or None for the §5.2 baseline).
    ``canonical`` (default) pads to the canonical program signature, so
    repeated single points — and the fig-suite sweeps — all reuse ONE
    compiled program per protocol instead of compiling a B=1 variant."""
    from repro.core.experiment import SweepSpec, run_sweep
    spec = SweepSpec(rates=(float(rate_tx_s),), seeds=(int(seed),),
                     scenarios=(scenario,), workloads=(workload,))
    return run_sweep(protocol, cfg, spec, canonical=canonical)[0]
