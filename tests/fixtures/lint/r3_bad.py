"""R3 static-args: undeclared SMRConfig field steering control flow."""
from dataclasses import dataclass
from functools import partial

import jax


@dataclass(frozen=True)
class SMRConfig:
    n_replicas: int = 5
    sim_seconds: float = 2.0


_jit = partial(jax.jit, static_argnames=("protocol", "cfg"))


# lint: traced-root
def step(cfg: SMRConfig, state):
    if cfg.batch_pipelining:  # expect: R3
        return state * 2
    if cfg.n_replicas > 3:
        return state
    return state + 1
