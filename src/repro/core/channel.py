"""Delayed-delivery message channels for the tick-based WAN simulator.

A channel is a ring buffer ``[Dmax, n, n, P]`` of payload vectors plus a
presence flag ``[Dmax, n, n]``; sender i's message to j written at arrival
slot ``(t + delay_ij) % Dmax``. All protocol payloads are designed to be
*monotone* (elementwise-max mergeable) — colliding deliveries merge into
the later state, which an omission-fault-tolerant protocol tolerates by
construction (DESIGN.md §8). The receive side folds arrivals into a
"latest state" matrix with elementwise max.

Two substrates share those semantics:

- the seed-era **per-channel** API (``make_channel``/``send``/``deliver``)
  — one ring dict per message type, 2 scatters + 1 clear per channel per
  tick; kept as the reference the packed path is pinned against
  (tests/test_channel.py);
- the **packed ring** (``RingSpec``/``make_ring``/``ring_deliver``/
  ``ring_commit``) — ALL of a protocol's channels concatenated along the
  field axis into one ``[Dmax, n, n, K]`` buffer (one flag field per
  channel), so a whole tick's traffic is one fused scatter-max + one
  scatter-add (additive counter channels) + one slot-clear, dispatched
  through ``repro.kernels.channel_ring`` (jnp oracle on CPU, Pallas dense
  kernel on TPU). Bitwise-equal to the per-channel path by construction:
  same slots, same merge ops, same neutral elements.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.channel_ring import ops as ring_ops

NEG = -1.0  # "absent" payload fill


def make_channel(dmax: int, n: int, p: int, additive: bool = False
                 ) -> Dict[str, jax.Array]:
    fill = 0.0 if additive else NEG
    return {
        "buf": jnp.full((dmax, n, n, p), fill, jnp.float32),
        "flag": jnp.zeros((dmax, n, n), jnp.bool_),
        "fill": jnp.float32(fill),
    }


def send(ch: Dict[str, jax.Array], t: jax.Array, payload: jax.Array,
         delay_ticks: jax.Array, mask: jax.Array, additive: bool = False,
         drop: jax.Array | None = None) -> Dict[str, jax.Array]:
    """payload: [n, n, P] (sender, receiver, fields); delay_ticks: [n, n]
    int32 >= 1; mask: [n, n] bool — which (i, j) actually send this tick.
    drop: optional [n, n] bool — links the network scenario cuts this tick
    (netsim.link_drop); a dropped send is a silent omission, which the
    monotone-payload protocols tolerate by construction.
    Merging policy: elementwise max (monotone payloads) or add (counters)."""
    if drop is not None:
        mask = mask & ~drop
    dmax = ch["buf"].shape[0]
    n = payload.shape[0]
    slot = (t + jnp.clip(delay_ticks, 1, dmax - 1)) % dmax          # [n, n]
    ii, jj = jnp.meshgrid(jnp.arange(n), jnp.arange(n), indexing="ij")
    if additive:
        merged = jnp.where(mask[..., None], payload, 0.0)
        buf = ch["buf"].at[slot, ii, jj].add(merged)
    else:
        merged = jnp.where(mask[..., None], payload, NEG)
        buf = ch["buf"].at[slot, ii, jj].max(merged)
    flag = ch["flag"].at[slot, ii, jj].max(mask)
    return {"buf": buf, "flag": flag, "fill": ch["fill"]}


def deliver(ch: Dict[str, jax.Array], t: jax.Array
            ) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Pop slot t. Returns (channel, flags [n,n], payload [n,n,P])."""
    dmax = ch["buf"].shape[0]
    slot = t % dmax
    flags = ch["flag"][slot]
    payload = ch["buf"][slot]
    buf = ch["buf"].at[slot].set(ch["fill"])
    flag = ch["flag"].at[slot].set(False)
    return {"buf": buf, "flag": flag, "fill": ch["fill"]}, flags, payload


def fold_state(state: jax.Array, flags: jax.Array, payload: jax.Array
               ) -> jax.Array:
    """Merge arrivals into latest-state matrix [n, n, P] (receiver, sender)."""
    # payload is (sender, receiver, P) -> transpose to (receiver, sender, P)
    arr = jnp.swapaxes(payload, 0, 1)
    fl = jnp.swapaxes(flags, 0, 1)[..., None]
    return jnp.where(fl, jnp.maximum(state, arr), state)


# --------------------------------------------------------------------------
# Packed ring: one fused delivery ring per protocol
# --------------------------------------------------------------------------

class ChannelSpec(NamedTuple):
    """One logical channel inside a packed ring."""
    name: str
    width: int                 # payload fields
    additive: bool = False     # add-merge (counters) instead of max-merge


@dataclass(frozen=True)
class RingSpec:
    """Static field layout of a protocol's packed ring.

    Channels are laid out in declaration order, each as its payload fields
    immediately followed by its own flag field —
      K = sum(width_c + 1)
    — so one send's whole contribution (payload + flag) is a single
    contiguous window of the field axis, which is what lets the fused
    commit scatter wide rows instead of single fields. Max-merged payload
    fields clear to ``NEG``; additive payload fields and all flag fields
    clear to 0.0 (flags merge by max either way).
    """
    channels: Tuple[ChannelSpec, ...]

    def __init__(self, *channels: ChannelSpec):
        object.__setattr__(self, "channels", tuple(channels))
        assert len({c.name for c in channels}) == len(channels), channels

    @property
    def k(self) -> int:
        return sum(c.width + 1 for c in self.channels)

    def offset(self, name: str) -> int:
        off = 0
        for c in self.channels:
            if c.name == name:
                return off
            off += c.width + 1
        raise KeyError(name)

    def flag(self, name: str) -> int:
        return self.offset(name) + self[name].width

    def __getitem__(self, name: str) -> ChannelSpec:
        for c in self.channels:
            if c.name == name:
                return c
        raise KeyError(name)

    def fill(self) -> np.ndarray:
        """Per-field clear value [K]: merge-neutral of each field."""
        # lint: allow(traced-purity): the ring layout is static — this
        # numpy vector is built once per trace and constant-folds into
        # the compiled program
        f = np.zeros((self.k,), np.float32)
        for c in self.channels:
            if not c.additive:
                f[self.offset(c.name):self.offset(c.name) + c.width] = NEG
        return f

    def layout(self, name: str) -> Tuple[int, int, int, bool]:
        """(payload offset, width, flag field, additive) — the static
        per-entry layout the kernels consume."""
        c = self[name]
        return (self.offset(name), c.width, self.flag(name), c.additive)


def ring_occupancy(spec: RingSpec, ring: Dict[str, jax.Array]) -> jax.Array:
    """Fraction of (slot, sender, receiver, channel) entries currently
    holding an undelivered message — the flag fields are >0.5 exactly
    while a send waits in its arrival slot, so this is a direct in-flight
    occupancy gauge of the delivery ring (repro.obs.monitor)."""
    flags = jnp.stack([ring["buf"][..., spec.flag(c.name)]
                       for c in spec.channels], axis=-1)
    return jnp.mean((flags > 0.5).astype(jnp.float32))


class Send(NamedTuple):
    """One buffered send of a tick: channel name + the legacy ``send``
    arguments. The per-tick send list of a protocol is static (same
    channels in the same order every tick), so it lowers to a fixed fused
    scatter."""
    name: str
    payload: jax.Array         # [n, n, P]
    delay_ticks: jax.Array     # [n, n] int32 >= 1 (clipped like send())
    mask: jax.Array            # [n, n] bool


def make_ring(spec: RingSpec, dmax: int, n: int) -> Dict[str, jax.Array]:
    fill = jnp.asarray(spec.fill())
    return {"buf": jnp.broadcast_to(fill, (dmax, n, n, spec.k)
                                    ).astype(jnp.float32)}


def ring_deliver(spec: RingSpec, ring: Dict[str, jax.Array], t: jax.Array
                 ) -> Dict[str, Tuple[jax.Array, jax.Array]]:
    """Read slot t of every channel at once (one gather). Returns
    {name: (flags [n, n] bool, payload [n, n, P])} — identical to what the
    per-channel ``deliver`` returns for each channel. The slot is NOT
    cleared here; ``ring_commit`` clears it (sends never target slot t, so
    the clear commutes across the tick)."""
    slot = ring["buf"][t % ring["buf"].shape[0]]         # [n, n, K]
    out = {}
    for c in spec.channels:
        off = spec.offset(c.name)
        out[c.name] = (slot[..., spec.flag(c.name)] > 0.5,
                       slot[..., off:off + c.width])
    return out


def ring_commit(spec: RingSpec, ring: Dict[str, jax.Array], t: jax.Array,
                sends: List[Send], drop: jax.Array | None = None,
                backend: str = "auto") -> Dict[str, jax.Array]:
    """Fused commit of one tick: clear the delivered slot ``t % Dmax`` and
    merge every buffered send — one scatter-max (+ one scatter-add if the
    spec has additive channels), via repro.kernels.channel_ring. ``drop``
    is the tick's scenario link-cut mask, applied to every send (silent
    omission), exactly as the per-channel path passed it to ``send``."""
    dmax = ring["buf"].shape[0]
    # the fused scatter-add sums duplicate rows in one op, which float
    # non-associativity could tell apart from sequential per-send adds —
    # the bitwise-equivalence contract therefore requires additive
    # channels to send at most once per tick (max-merged channels may
    # repeat freely: max is order-free)
    add_names = [s.name for s in sends if spec[s.name].additive]
    assert len(add_names) == len(set(add_names)), \
        f"additive channel sent twice in one tick: {add_names}"
    entries, layout = [], []
    for s in sends:
        c = spec[s.name]
        mask = s.mask if drop is None else s.mask & ~drop
        slot = (t + jnp.clip(s.delay_ticks, 1, dmax - 1)) % dmax
        neutral = 0.0 if c.additive else NEG
        vals = jnp.where(mask[..., None], s.payload, neutral)
        entries.append((slot, vals, mask.astype(jnp.float32)))
        layout.append(spec.layout(s.name))
    buf = ring_ops.ring_commit(ring["buf"], t, jnp.asarray(spec.fill()),
                               entries, layout, backend=backend)
    return {"buf": buf}
