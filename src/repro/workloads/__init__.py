"""Declarative client traffic — the other half of the scenario engine.

A ``Workload`` is a named list of composable traffic-shape primitives
(open-loop Poisson, on/off bursts, diurnal ramps, flash crowds,
WPaxos-style migrating region skew, Atlas-style closed-loop geo-placed
client pools). ``compile.lower`` turns one into fixed-shape windowed
per-origin rate tables that stack leaf-wise and ride through the batched
experiment engine (``experiment.SweepSpec.workloads``) as a third sweep
axis of ONE compiled program per protocol.

The bare ``PoissonOpen()`` workload compiles to the all-ones table and a
static fast path that is instruction-identical to the seed-era scalar
rate, keeping the fig 6-9 artifacts byte-identical (pinned by
tests/test_workloads.py).
"""
from repro.workloads.compile import (
    TRIVIAL_MODE,
    WorkloadMode,
    as_workload,
    is_trivial,
    lower,
    mode_of,
)
from repro.workloads.primitives import (
    ClosedLoop,
    DiurnalRamp,
    FlashCrowd,
    OnOffBurst,
    PoissonOpen,
    RegionSkew,
    Workload,
)

__all__ = [
    "ClosedLoop", "DiurnalRamp", "FlashCrowd", "OnOffBurst", "PoissonOpen",
    "RegionSkew", "Workload", "WorkloadMode", "TRIVIAL_MODE",
    "as_workload", "compile", "is_trivial", "lower", "mode_of",
]
