"""Declarative WAN adversary scenarios.

A ``Scenario`` is a named list of composable event primitives (crash
intervals, partitions, regional outages, gray failures, targeted delay
attacks, bandwidth throttles). ``compile.lower`` turns one into fixed-shape
windowed tables that ``netsim.build_env`` embeds into the array-native env,
so any scenario stacks leaf-wise (``netsim.stack_envs``) and vmaps through
the batched experiment engine unchanged.

The seed-era ``netsim.FaultSchedule`` fault model is gone; its exact
semantics live on as primitives (permanent ``Crash`` events, the seeded
random-minority ``TargetedDelay``), pinned bitwise against the seed-era
reference by tests/test_scenarios.py.
"""
from repro.scenarios.primitives import (
    BandwidthThrottle,
    Crash,
    GrayFailure,
    Partition,
    Recover,
    RegionOutage,
    Scenario,
    TargetedDelay,
)
from repro.scenarios.compile import as_scenario, lower

__all__ = [
    "BandwidthThrottle", "Crash", "GrayFailure", "Partition", "Recover",
    "RegionOutage", "Scenario", "TargetedDelay",
    "as_scenario", "lower",
]
