"""Workload engine (repro/workloads): primitive -> rate-table lowering
invariants (conservation against each primitive's analytic expectation),
closed-loop in-flight bounds, the trivial fast path that keeps the fig 6-9
artifacts byte-identical (uniform table path == seed-era scalar path,
bitwise), heterogeneous workload grids batching through run_sweep as ONE
compiled program, and the analytic baselines consuming the same compiled
tables."""
import math

import numpy as np
import pytest

from repro.configs.smr import SMRConfig
from repro.core import experiment
from repro.core.experiment import SweepSpec, run_sweep
from repro.core.harness import run_sim
from repro.scenarios import library as scenario_library
from repro.workloads import (
    ClosedLoop,
    DiurnalRamp,
    FlashCrowd,
    OnOffBurst,
    PoissonOpen,
    RegionSkew,
    Workload,
    as_workload,
    is_trivial,
    lower,
    mode_of,
)
from repro.workloads import library

CFG = SMRConfig(sim_seconds=2.0)
N = CFG.n_replicas
SCALARS = ("throughput", "median_ms", "p99_ms", "committed")


def _offered(cfg, wl):
    """Mean per-origin rate multiplier over the whole run, [n]."""
    tab = lower(cfg, wl)
    return tab["rate_of"][tab["win_of_tick"]].mean(axis=0)


def _assert_point_equal(a, b):
    for k in SCALARS:
        assert (a[k] == b[k]) or (np.isnan(a[k]) and np.isnan(b[k])), \
            f"{k}: {a[k]} != {b[k]}"
    np.testing.assert_array_equal(a["timeline"], b["timeline"])


# ------------------------------------------------- lowering invariants ----

def test_onoff_burst_conserves_analytic_load():
    """Total offered load == duty*on + (1-duty)*off, exactly, when the
    period divides the run (windows align with tick edges)."""
    for duty, on, off in ((0.5, 2.0, 0.0), (0.4, 2.5, 0.0), (0.25, 2.0, 1.0)):
        wl = Workload("b", (OnOffBurst(period_s=0.5, duty=duty,
                                       on_scale=on, off_scale=off),))
        want = duty * on + (1 - duty) * off
        np.testing.assert_allclose(_offered(CFG, wl), want, rtol=1e-6)


def test_diurnal_ramp_averages_midpoint():
    wl = Workload("d", (DiurnalRamp(period_s=2.0, low=0.25, high=1.75,
                                    step_s=0.125),))
    np.testing.assert_allclose(_offered(CFG, wl), (0.25 + 1.75) / 2,
                               rtol=2e-3)


def test_flash_crowd_rectangle_analytic():
    """decay_s=0 is a clean rectangle: target origin gains exactly
    (magnitude-1) x duration/sim extra load; others are untouched."""
    wl = Workload("f", (FlashCrowd(at_s=0.5, duration_s=0.5, magnitude=8.0,
                                   targets=(2,), decay_s=0.0),))
    got = _offered(CFG, wl)
    want = np.ones(N)
    want[2] = 1.0 + (8.0 - 1.0) * 0.5 / CFG.sim_seconds
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_region_skew_conserves_and_migrates():
    wl = Workload("s", (RegionSkew(hot_frac=0.8, hot=(0,), migrate_s=0.5),))
    tab = lower(CFG, wl)
    # every window conserves the total (sum of multipliers == n)
    np.testing.assert_allclose(tab["rate_of"].sum(axis=1), N, rtol=1e-6)
    # the hotspot visits 0,1,2,3 across the four migration windows
    hot_of_win = tab["rate_of"].argmax(axis=1)
    assert hot_of_win.tolist() == [0, 1, 2, 3]
    assert tab["rate_of"][0, 0] == pytest.approx(N * 0.8)
    assert tab["rate_of"][0, 1] == pytest.approx(N * 0.2 / (N - 1))


def test_closed_loop_lowering_and_validation():
    tab = lower(CFG, Workload("c", (ClosedLoop(think_ms=40.0, cap=64.0),)))
    assert float(tab["closed"]) == 1.0
    assert float(tab["think_ticks"]) == 40.0 / CFG.tick_ms
    assert float(tab["cap"]) == 64.0
    with pytest.raises(ValueError, match="one ClosedLoop"):
        lower(CFG, Workload("cc", (ClosedLoop(), ClosedLoop())))
    with pytest.raises(ValueError, match="placement"):
        lower(CFG, Workload("cp", (ClosedLoop(placement=(1.0, 2.0)),)))
    # geo placement redistributes but conserves
    w = (0.4, 0.3, 0.15, 0.1, 0.05)
    tab = lower(CFG, Workload("cg", (ClosedLoop(placement=w),)))
    np.testing.assert_allclose(tab["rate_of"][0], np.array(w) * N, rtol=1e-6)


def test_trivial_detection_and_mode():
    assert is_trivial(lower(CFG, None))
    assert is_trivial(lower(CFG, Workload("p", (PoissonOpen(),))))
    assert not is_trivial(lower(CFG, Workload("p2", (PoissonOpen(2.0),))))
    assert not is_trivial(lower(CFG, library.get("onoff-burst", 2.0)))
    mode = mode_of([lower(CFG, None),
                    lower(CFG, library.get("closed-loop", 2.0))])
    assert (mode.trivial, mode.closed) == (False, True)
    with pytest.raises(TypeError):
        as_workload("poisson-open")


def test_library_compiles_and_pads():
    lib = library.workloads(CFG.sim_seconds, N)
    assert set(library.NAMES) == set(lib)
    from repro.workloads import compile as wcompile
    pad = max(wcompile.n_windows(CFG, w) for w in lib.values())
    for w in lib.values():
        tab = lower(CFG, w, pad_windows=pad)
        assert tab["rate_of"].shape == (pad, N)
    with pytest.raises(KeyError, match="unknown workload"):
        library.get("tsunami", 2.0)


# ------------------------------------------------- simulator semantics ----

def test_trivial_and_uniform_table_paths_agree_bitwise():
    """The pin behind the byte-identical fig 6-9 artifacts: an all-ones
    rate table forced down the non-trivial gather path produces exactly
    the seed-era scalar-broadcast results."""
    cfg = SMRConfig(sim_seconds=1.0)
    # on == off == 1.0 keeps the table all-ones but W > 1, defeating the
    # trivial fast-path detection
    uniform = Workload("uniform", (OnOffBurst(period_s=0.25, duty=0.5,
                                              on_scale=1.0, off_scale=1.0),))
    assert not is_trivial(lower(cfg, uniform))
    for proto in ("mandator-sporades", "multipaxos"):
        a = run_sim(proto, cfg, rate_tx_s=20_000)
        b = run_sim(proto, cfg, rate_tx_s=20_000, workload=uniform)
        _assert_point_equal(a, b)
        np.testing.assert_array_equal(a["origin_timeline"],
                                      b["origin_timeline"])


def test_closed_loop_inflight_never_exceeds_cap():
    cfg = SMRConfig(sim_seconds=1.0)
    wl = Workload("tight", (ClosedLoop(think_ms=20.0, cap=64.0),))
    r = run_sim("mandator-sporades", cfg, rate_tx_s=200_000, workload=wl)
    assert np.all(np.asarray(r["inflight_max"]) <= 64.0 + 1e-6), \
        r["inflight_max"]
    # the cap binds under this load (the pool saturates, not idles)
    assert np.asarray(r["inflight_max"]).max() == pytest.approx(64.0)
    # Little's law: committed throughput can't exceed the cap's bound
    assert r["throughput"] <= N * 64.0 / (r["median_ms"] / 1000.0) * 1.5


def test_closed_loop_feedback_throttles_offered_load():
    """A closed pool submits less than its open-loop twin at the same
    sweep rate once latency eats into the think-time budget."""
    cfg = SMRConfig(sim_seconds=1.0)
    closed = run_sim("mandator-sporades", cfg, rate_tx_s=100_000,
                     workload=library.get("closed-loop", 1.0, N))
    open_ = run_sim("mandator-sporades", cfg, rate_tx_s=100_000)
    assert closed["committed"] < open_["committed"]
    assert closed["throughput"] > 0


def test_region_skew_reports_per_origin_latency():
    cfg = SMRConfig(sim_seconds=1.0)
    r = run_sim("mandator-sporades", cfg, rate_tx_s=50_000,
                workload=Workload("skew", (RegionSkew(hot_frac=0.8,
                                                      hot=(0,)),)))
    med = np.asarray(r["origin_median_ms"])
    assert med.shape == (N,)
    assert np.isfinite(med[0])  # the hot origin definitely committed
    assert r["origin_timeline"].shape[0] == N
    # the hot origin carries most of the committed load
    per_origin = np.asarray(r["origin_timeline"]).sum(axis=1)
    assert per_origin[0] > 0.5 * per_origin.sum()


# ------------------------------------------- batched sweep + baselines ----

def test_workload_grid_is_one_compiled_program_and_matches_sequential():
    """workload × scenario × rate grid through run_sweep: ONE trace per
    protocol, every point bitwise-equal to its single run_sim — including
    open-loop lanes sharing a program with closed-loop lanes."""
    cfg = SMRConfig(sim_seconds=1.0)
    scen = scenario_library.scenarios(cfg.sim_seconds, N)
    wls = (None, library.get("onoff-burst", cfg.sim_seconds, N),
           library.get("closed-loop", cfg.sim_seconds, N))
    spec = SweepSpec(rates=(10_000, 30_000),
                     scenarios=(scen["baseline"], scen["paper-ddos"]),
                     workloads=wls)
    experiment.reset_trace_counts()
    grid = run_sweep("mandator-sporades", cfg, spec)
    # zero traces means an earlier test already compiled the shared
    # canonical program — the one-program claim is the signature count
    assert experiment.trace_counts().get("mandator-sporades", 0) <= 1, \
        "a workload × scenario × rate grid must compile as ONE program"
    assert len(experiment.program_signatures()["mandator-sporades"]) == 1
    assert len(grid) == spec.size == 12
    for r, (rate, seed, fi, wi) in zip(grid, spec.points()):
        single = run_sim("mandator-sporades", cfg, rate_tx_s=rate,
                         scenario=spec.scenarios[fi], seed=seed,
                         workload=wls[wi])
        _assert_point_equal(r, single)


def test_analytic_baselines_consume_workload_tables():
    cfg = SMRConfig(sim_seconds=5.0)
    # patient pools: a long think time keeps the Little's-law equilibrium
    # rate above the models' full-batch formation threshold (they form no
    # partial batches — the same sub-threshold collapse their open-loop
    # curves show at low rates)
    patient = Workload("patient", (ClosedLoop(think_ms=2000.0, cap=1e6),))
    for proto, rate in (("epaxos", 8_000), ("rabia", 2_000)):
        base = run_sweep(proto, cfg, SweepSpec(rates=(rate,)))[0]
        burst = run_sweep(proto, cfg, SweepSpec(
            rates=(rate,),
            workloads=(library.get("onoff-burst", cfg.sim_seconds, N),)))[0]
        closed = run_sweep(proto, cfg, SweepSpec(
            rates=(rate,), workloads=(patient,)))[0]
        assert base["workload"] == "poisson-open"
        assert burst["workload"] == "onoff-burst"
        assert base["throughput"] > 0
        assert closed["throughput"] > 0
        # bursty traffic changes the model's answer (table is read)
        assert burst["committed"] != base["committed"]
        # closed loop can't commit more than the open offered rate
        assert closed["committed"] <= base["committed"] + 1e-6


def test_fault_schedule_is_removed():
    """The deprecated seed-era shim is gone (deprecated in PR 3,
    removed in PR 5) — new callers pass Scenarios to run_sweep/run_sim."""
    from repro.core import netsim
    assert not hasattr(netsim, "FaultSchedule")
