"""Flight-recorder inspector: run ONE traced sweep point, print its
per-phase latency breakdown + event summary, and export a Chrome/Perfetto
``trace_event`` JSON that loads directly at ui.perfetto.dev (or
chrome://tracing):

  PYTHONPATH=src python -m benchmarks.inspect \\
      --protocol mandator-sporades --scenario paper-ddos \\
      --rate 300000 --out trace.json

The point runs at ``TraceLevel.FULL`` through the same batched experiment
engine as every figure suite (one canonical compiled program per
protocol — tracing levels compile their own variants, the default
``off`` program is untouched). ``--level counters`` skips the event ring
(phase table + event counts only, no trace file).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.smr import SMRConfig  # noqa: E402
from repro.core import compile_cache  # noqa: E402
from repro.core.experiment import SweepSpec, run_sweep  # noqa: E402
from repro.core.harness import SCAN_PROTOCOLS  # noqa: E402
from repro.obs import decode, export  # noqa: E402
from repro.obs import monitor as obs_monitor  # noqa: E402
from repro.obs.trace import TraceLevel  # noqa: E402
from repro.scenarios import library as scenario_library  # noqa: E402
from repro.workloads import library as workload_library  # noqa: E402


def inspect_point(protocol: str, rate: float, scenario: str = "",
                  workload: str = "", sim_seconds: float = 4.0,
                  seed: int = 0, level: str = TraceLevel.FULL,
                  trace_events: int = 512, out: str = "trace.json",
                  health: bool = False) -> Path:
    """Run + export one traced point; returns the trace path (or None at
    ``counters`` level, which has no event ring to export). ``health``
    additionally runs the on-device invariant monitor at full level and
    prints the verdict + per-replica gauge table."""
    cfg = SMRConfig(sim_seconds=sim_seconds, trace_level=level,
                    trace_events=trace_events,
                    monitor_level=obs_monitor.MonitorLevel.FULL
                    if health else obs_monitor.MonitorLevel.OFF)
    scen = scenario_library.get(scenario, sim_seconds, cfg.n_replicas) \
        if scenario else None
    wl = workload_library.get(workload, sim_seconds, cfg.n_replicas) \
        if workload else None
    spec = SweepSpec(rates=(rate,), seeds=(seed,), scenarios=(scen,),
                     workloads=(wl,))
    r = run_sweep(protocol, cfg, spec)[0]

    print(f"== {protocol} @ {rate:,.0f} tx/s"
          + (f" under {scenario!r}" if scenario else "")
          + (f" with workload {workload!r}" if workload else "")
          + f" ({sim_seconds:.0f}s sim, trace level {level}) ==")
    print(f" throughput {r['throughput']:,.0f} tx/s, "
          f"median {r['median_ms']:.0f} ms, p99 {r['p99_ms']:.0f} ms\n")
    print(export.phase_table(r))

    if health:
        print()
        print(obs_monitor.health_table(r))

    decoded = decode.decode_result(r)
    if decoded:
        print("\n cluster event counts (per protocol layer):")
        for layer, counts in decode.event_summary(decoded).items():
            cells = ", ".join(f"{k}={v}" for k, v in counts.items()) or "-"
            dropped = sum(rep.get("dropped", 0) for rep in decoded[layer])
            tail = f"  [ring dropped {dropped}]" if dropped else ""
            print(f"   {layer:10s} {cells}{tail}")

    if level != TraceLevel.FULL:
        print("\n# no event ring at this level; rerun with --level full "
              "for the Perfetto export")
        return None
    trace = export.chrome_trace(r, cfg, protocol, scenario=scen)
    p = export.write(out, trace)
    print(f"\n# wrote {p} ({len(trace['traceEvents'])} trace events) — "
          "open at https://ui.perfetto.dev")
    return p


def print_analysis(path) -> None:
    """Render a tracelint findings artifact (``python -m repro.analysis
    --json PATH``) as the shared findings table — the static-analysis
    view next to the runtime ``--health``/trace views."""
    from repro.analysis import format_table
    from repro.analysis.findings import findings_from_json
    findings = findings_from_json(json.loads(Path(path).read_text()))
    active = sum(1 for f in findings if f.active)
    print(f"== tracelint findings ({path}): {len(findings)} total, "
          f"{active} active ==")
    for line in format_table(findings):
        print(f" {line}")
    print()


def print_scaling(path) -> None:
    """Render a mesh-sharded scaling curve as the points/sec-vs-devices
    table with the per-device-count compile/run split. Accepts either the
    ``benchmarks/artifacts/scaling.json`` artifact or a BENCH_core.json
    (whose ``scaling`` suite embeds the same block)."""
    data = json.loads(Path(path).read_text())
    entry = data.get("suites", {}).get("scaling", data)
    block = entry.get("scaling", entry)
    curve = block.get("curve")
    if not curve:
        print(f"== no scaling curve in {path} ==")
        return
    grid = block.get("grid", {})
    gdesc = " x ".join(f"{v} {k}" for k, v in grid.items()) or "?"
    print(f"== mesh-sharded scaling curve ({path}) ==")
    print(f" protocol {block.get('protocol', '?')}, "
          f"{curve[0].get('points', '?')} points ({gdesc}), "
          f"sim {block.get('sim_seconds', '?')}s, "
          f"sketch bins {block.get('sketch_bins', '?')}, "
          f"parity {block.get('parity', '?')}")
    hdr = (f" {'devices':>8} {'dispatch_s':>11} {'run_s':>8} "
           f"{'wall_s':>8} {'points/s':>10} {'speedup':>8}")
    print(hdr)
    base = curve[0].get("points_per_s") or 1.0
    for c in curve:
        print(f" {c['devices']:>8} {c.get('dispatch_s', 0.0):>11.3f} "
              f"{c.get('run_s', 0.0):>8.3f} {c.get('wall_s', 0.0):>8.3f} "
              f"{c.get('points_per_s', 0.0):>10.1f} "
              f"{c.get('points_per_s', 0.0) / base:>7.2f}x")
    print()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="run one traced sweep point and export a "
                    "Chrome/Perfetto trace")
    ap.add_argument("--protocol", default="mandator-sporades",
                    choices=SCAN_PROTOCOLS)
    ap.add_argument("--scenario", default="",
                    help="adversary from the curated library: "
                         f"{', '.join(scenario_library.NAMES)}")
    ap.add_argument("--workload", default="",
                    help="traffic shape from the curated library: "
                         f"{', '.join(workload_library.NAMES)}")
    ap.add_argument("--rate", type=float, default=300_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sim-seconds", type=float, default=4.0)
    ap.add_argument("--level", default=TraceLevel.FULL,
                    choices=(TraceLevel.COUNTERS, TraceLevel.FULL))
    ap.add_argument("--trace-events", type=int, default=512,
                    help="per-replica event-ring capacity (oldest dropped)")
    ap.add_argument("--out", default="trace.json")
    ap.add_argument("--health", action="store_true",
                    help="run the consensus health monitor at full level "
                         "and print the invariant verdict + gauge table "
                         "(composes with --scenario/--workload)")
    ap.add_argument("--analysis", default="", metavar="PATH",
                    help="print the tracelint findings table from a "
                         "`python -m repro.analysis --json PATH` artifact "
                         "before the point run (composes with --health)")
    ap.add_argument("--scaling", default="", metavar="PATH",
                    help="print the mesh-sharded points/sec-vs-devices "
                         "table from a benchmarks/artifacts/scaling.json "
                         "or BENCH_core.json, then exit")
    ap.add_argument("--no-compile-cache", action="store_true")
    args = ap.parse_args(argv)
    if args.scaling:
        print_scaling(args.scaling)
        return
    if args.analysis:
        print_analysis(args.analysis)
    if args.no_compile_cache:
        compile_cache.disable()
    else:
        print(f"# persistent compile cache: {compile_cache.enable()}",
              file=sys.stderr)
    inspect_point(args.protocol, args.rate, scenario=args.scenario,
                  workload=args.workload, sim_seconds=args.sim_seconds,
                  seed=args.seed, level=args.level,
                  trace_events=args.trace_events, out=args.out,
                  health=args.health)


if __name__ == "__main__":
    main()
