"""Flight recorder (repro/obs): the OFF level is bitwise inert for every
scan protocol (the tentpole invariant — tracing must never perturb the
physics), the event ring keeps the newest ``cap`` events with a saturating
dropped counter, decode round-trips a hand-built ring, mode-switch events
fire exactly when the paper says they should (paper-ddos yes, baseline
no), and the four phase latencies telescope to the end-to-end commit
latency batch by batch."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.smr import SMRConfig
from repro.core.experiment import SweepSpec, run_sweep
from repro.core.harness import SCAN_PROTOCOLS, run_sim
from repro.obs import decode, export
from repro.obs.trace import (
    DEFAULT_SPEC,
    PHASES,
    TraceLevel,
    _SAT,
    init_trace,
    record,
)
from repro.scenarios import Crash, Scenario

SIM_S = 1.0
RATE = 50_000.0
# a crash mid-run so the equivalence also covers the env-event recording
# path (crash/recover edges, drop masks under dead links)
CRASH = Scenario("half-crash", (Crash(start_s=SIM_S / 2, targets=(0,)),))

# keys every scan protocol emits that are plain metric arrays (the obs /
# phase keys are additions, not perturbations — asserted separately)
METRIC_KEYS = ("throughput", "median_ms", "p99_ms", "committed", "timeline",
               "origin_median_ms", "origin_p99_ms", "origin_timeline",
               "origin_lat_ms_timeline")


# ----------------------------------------------- off == traced, bitwise --

@pytest.mark.parametrize("protocol", SCAN_PROTOCOLS)
@pytest.mark.parametrize("scenario", [None, CRASH],
                         ids=["baseline", "crash"])
def test_trace_level_off_is_bitwise_inert(protocol, scenario):
    """Every metric is bit-identical across off/counters/full: the
    recorder only ever *reads* protocol state, and at OFF it is compiled
    out entirely."""
    outs = {}
    for level in TraceLevel.ORDER:
        cfg = SMRConfig(sim_seconds=SIM_S, trace_level=level,
                        trace_events=32)
        outs[level] = run_sim(protocol, cfg, RATE, scenario=scenario)
    for level in (TraceLevel.COUNTERS, TraceLevel.FULL):
        for k in METRIC_KEYS:
            np.testing.assert_array_equal(
                np.asarray(outs[TraceLevel.OFF][k]),
                np.asarray(outs[level][k]),
                err_msg=f"{protocol}/{level}/{k}")
    # the traced runs actually carry the additions
    assert "obs" not in outs[TraceLevel.OFF]
    assert "phase_med_ms" not in outs[TraceLevel.OFF]
    for level in (TraceLevel.COUNTERS, TraceLevel.FULL):
        assert outs[level]["obs"]
        assert outs[level]["phase_med_ms"].shape == (len(PHASES),)


def test_off_config_is_the_default():
    assert SMRConfig().trace_level == TraceLevel.OFF


# ----------------------------------------------- ring overflow semantics --

def test_ring_overflow_keeps_newest_and_saturates():
    """10 events into a cap-4 ring: the ring holds the newest 4 in order,
    dropped counts the 6 evicted, and a saturated counter stays put."""
    n, cap = 2, 4
    ts = init_trace(DEFAULT_SPEC, TraceLevel.FULL, n, cap)
    mask = jnp.array([True, False])  # replica 1 stays silent throughout
    for i in range(10):
        ts = record(DEFAULT_SPEC, ts, "commit", mask, t=i, a=100 + i, b=i)
    reps = decode.decode_ring(ts)
    assert [e["tick"] for e in reps[0]["events"]] == [6, 7, 8, 9]
    assert [e["args"]["key"] for e in reps[0]["events"]] == [106, 107, 108,
                                                             109]
    assert reps[0]["dropped"] == 6
    assert reps[0]["counts"]["commit"] == 10
    # the silent replica recorded nothing and dropped nothing
    assert reps[1]["events"] == []
    assert reps[1]["dropped"] == 0
    # saturation: a counter at the cap never wraps
    ts = dict(ts)
    ts["dropped"] = jnp.full((n,), _SAT, jnp.int32)
    ts = record(DEFAULT_SPEC, ts, "commit", mask, t=11)
    assert np.all(np.asarray(ts["dropped"]) == int(_SAT))


def test_ring_exact_capacity_no_drop():
    ts = init_trace(DEFAULT_SPEC, TraceLevel.FULL, 1, 3)
    for i in range(3):
        ts = record(DEFAULT_SPEC, ts, "view_change", jnp.array([True]), t=i,
                    a=i)
    rep = decode.decode_ring(ts)[0]
    assert [e["tick"] for e in rep["events"]] == [0, 1, 2]
    assert rep["dropped"] == 0


# ----------------------------------------------- decode round-trip --------

def test_decode_round_trip_hand_built_sequence():
    """Events written through the recorder come back name-for-name,
    arg-for-arg, in arrival order."""
    seq = [("view_change", 3, {"view": 1, "round": 7}),
           ("mode_switch", 5, {"is_async": 1, "view": 1}),
           ("commit", 9, {"key": 2**26, "total": 123}),  # int32-range key
           ("crash", 12, {"view": 2, "round": 9})]
    ts = init_trace(DEFAULT_SPEC, TraceLevel.FULL, 1, 8)
    for name, t, args in seq:
        an, bn = DEFAULT_SPEC.args_of(name)
        ts = record(DEFAULT_SPEC, ts, name, jnp.array([True]), t=t,
                    a=args[an], b=args[bn])
    rep = decode.decode_ring(ts)[0]
    assert [(e["name"], e["tick"], e["args"]) for e in rep["events"]] == seq
    assert rep["counts"]["commit"] == 1 and rep["counts"]["crash"] == 1


# ----------------------------------------------- mode-switch semantics ----

def test_mode_switch_fires_under_ddos_not_baseline():
    """Sporades switches sync->async only when the adversary makes it:
    paper-ddos forces mode switches, the fault-free baseline never does."""
    from repro.scenarios import library as scenario_library
    cfg = SMRConfig(sim_seconds=2.0, trace_level=TraceLevel.COUNTERS)
    ddos = scenario_library.get("paper-ddos", 2.0)
    spec = SweepSpec(rates=(200_000.0,), scenarios=(None, ddos))
    base, attacked = run_sweep("mandator-sporades", cfg, spec)
    kind = DEFAULT_SPEC.kind("mode_switch")
    n_base = int(np.asarray(base["obs"]["sporades"]["counts"])[:, kind].sum())
    n_ddos = int(
        np.asarray(attacked["obs"]["sporades"]["counts"])[:, kind].sum())
    assert n_base == 0
    assert n_ddos >= 1
    assert attacked["async_frac"] > 0


# ----------------------------------------------- phase accounting ---------

@pytest.mark.parametrize("protocol", SCAN_PROTOCOLS)
def test_phases_telescope_to_end_to_end(protocol):
    """Per committed batch: the four marks are ordered (create <= stable
    <= commit <= deliver), every phase is non-negative, and the phases sum
    to the arrival->delivery latency exactly (the marks telescope; the
    only slack allowed is one tick of quantization)."""
    cfg = SMRConfig(sim_seconds=SIM_S, trace_level=TraceLevel.FULL)
    r = run_sim(protocol, cfg, RATE)
    marks = np.asarray(r["batch_marks_t"])          # [4, n, R] ticks
    arr = np.asarray(r["batch_arr_t"])              # [n, R]
    cnt = np.asarray(r["batch_n"])
    ok = np.isfinite(marks).all(axis=0) & (cnt > 0)
    assert ok.sum() > 0
    create, stable, commit, deliver = (marks[j][ok] for j in range(4))
    assert np.all(create <= stable + 1e-6)
    assert np.all(stable <= commit + 1e-6)
    assert np.all(commit <= deliver + 1e-6)
    phases = np.stack([create - arr[ok], stable - create, commit - stable,
                       deliver - commit]) * cfg.tick_ms
    assert np.all(phases >= -1e-6)
    e2e = (deliver - arr[ok]) * cfg.tick_ms
    np.testing.assert_allclose(phases.sum(axis=0), e2e, atol=cfg.tick_ms)
    # commit latency reconstructed from the marks matches the headline
    # metric's input (commit - arrival), so the breakdown explains the
    # number the figures report
    assert np.all(np.isfinite(np.asarray(r["phase_med_ms"])))
    om = np.asarray(r["phase_origin_med_ms"])
    assert om.shape == (len(PHASES), cfg.n_replicas)


def test_analytic_baselines_emit_phases():
    """epaxos/rabia (host-side models) carry the same phase schema when
    traced, and none at OFF."""
    for proto in ("epaxos", "rabia"):
        cfg = SMRConfig(sim_seconds=2.0, trace_level=TraceLevel.COUNTERS)
        rate = 5_000.0 if proto == "epaxos" else 800.0
        r = run_sweep(proto, cfg, SweepSpec(rates=(rate,)))[0]
        assert export.phases_dict(r) is not None, proto
        assert len(r["phase_med_ms"]) == len(PHASES)
        r0 = run_sweep(proto, SMRConfig(sim_seconds=2.0),
                       SweepSpec(rates=(rate,)))[0]
        assert "phase_med_ms" not in r0


# ----------------------------------------------- export schema ------------

def test_chrome_trace_export_validates():
    cfg = SMRConfig(sim_seconds=SIM_S, trace_level=TraceLevel.FULL)
    r = run_sim("mandator-sporades", cfg, RATE, scenario=CRASH)
    trace = export.chrome_trace(r, cfg, "mandator-sporades", scenario=CRASH)
    export.validate(trace)  # raises on schema violations
    names = {e["name"] for e in trace["traceEvents"]}
    assert "dissemination" in names and "consensus" in names
    assert "Crash" in names          # the scenario window made it in
    phs = {e["ph"] for e in trace["traceEvents"]}
    assert {"M", "X", "C"} <= phs


def test_chrome_trace_requires_full_level():
    cfg = SMRConfig(sim_seconds=SIM_S)
    r = run_sim("mandator-sporades", cfg, RATE)
    with pytest.raises(ValueError, match="flight-recorder"):
        export.chrome_trace(r, cfg, "mandator-sporades")
