"""Declarative WAN adversary scenarios.

A ``Scenario`` is a named list of composable event primitives (crash
intervals, partitions, regional outages, gray failures, targeted delay
attacks, bandwidth throttles). ``compile.lower`` turns one into fixed-shape
windowed tables that ``netsim.build_env`` embeds into the array-native env,
so any scenario stacks leaf-wise (``netsim.stack_envs``) and vmaps through
the batched experiment engine unchanged.

``netsim.FaultSchedule`` (the seed-era fault model) is kept as a thin
compatibility shim: ``as_scenario`` compiles it to an equivalent Scenario
(see ``compile.from_fault_schedule``) with bitwise-identical env tables.
"""
from repro.scenarios.primitives import (
    BandwidthThrottle,
    Crash,
    GrayFailure,
    Partition,
    Recover,
    RegionOutage,
    Scenario,
    TargetedDelay,
)
from repro.scenarios.compile import as_scenario, from_fault_schedule, lower

__all__ = [
    "BandwidthThrottle", "Crash", "GrayFailure", "Partition", "Recover",
    "RegionOutage", "Scenario", "TargetedDelay",
    "as_scenario", "from_fault_schedule", "lower",
]
