"""§Roofline report: per (arch x shape) terms from the dry-run artifacts.

Reads benchmarks/artifacts/dryrun/*.json (produced by repro.launch.dryrun),
emits the single-pod roofline table (+ the multi-pod compile check) as
markdown + CSV rows. Hardware constants: TPU v5e — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI (distributed/hlo_analysis.py).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Tuple

ART = Path(__file__).resolve().parent / "artifacts"
DRY = ART / "dryrun"

Row = Tuple[str, float, str]


def load(mesh: str) -> List[dict]:
    out = []
    for p in sorted(DRY.glob(f"*__{mesh}.json")):
        out.append(json.loads(p.read_text()))
    return out


def table(mesh: str = "single") -> str:
    recs = load(mesh)
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | MODEL_FLOPS/HLO | bound (ms) | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | skipped: full-attention (no sub-quadratic "
                         f"path) |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.1f} | "
            f"{r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} | "
            f"{r['dominant']} | {r['useful_flop_ratio']:.2f} | "
            f"{r['bound_s']*1e3:.1f} | |")
    return "\n".join(lines)


def rows(mesh: str = "single") -> List[Row]:
    out: List[Row] = []
    for r in load(mesh):
        if "skipped" in r:
            out.append((f"roofline/{r['arch']}/{r['shape']}/{mesh}", 0.0,
                        "skipped=1"))
            continue
        out.append((
            f"roofline/{r['arch']}/{r['shape']}/{mesh}",
            r["bound_s"] * 1e6,
            f"dominant={r['dominant']};compute_ms={r['compute_s']*1e3:.2f};"
            f"memory_ms={r['memory_s']*1e3:.2f};"
            f"collective_ms={r['collective_s']*1e3:.2f};"
            f"useful={r['useful_flop_ratio']:.3f}"))
    return out


def summary(mesh: str = "single") -> dict:
    recs = [r for r in load(mesh) if "skipped" not in r]
    doms = {}
    for r in recs:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return {"cells": len(recs), "dominant_counts": doms,
            "mean_useful": sum(r["useful_flop_ratio"] for r in recs)
            / max(len(recs), 1)}


def main() -> None:
    for mesh in ("single", "multi"):
        recs = load(mesh)
        if not recs:
            continue
        md = table(mesh)
        (ART / f"roofline_{mesh}.md").write_text(md)
        print(f"# roofline ({mesh}): {len(recs)} cells -> "
              f"{ART}/roofline_{mesh}.md")
        print(json.dumps(summary(mesh), indent=1))


if __name__ == "__main__":
    main()
