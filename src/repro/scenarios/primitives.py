"""Composable WAN adversary primitives.

Each primitive is a frozen dataclass with a time window (seconds) and a
target selector, and knows how to *paint* itself onto the windowed env
tables the compiler builds (see compile.py):

  alive[w, n]          replica up/down per window
  drop[w, n, n]        link drop mask (sender, receiver)
  extra_delay[w, n, n] extra one-way delay in ticks
  nic_scale[w, n]      egress bandwidth multiplier per sender

Composition rules (primitives are applied in Scenario order):
  alive       — last writer wins (so ``Recover`` can undo a ``Crash``),
  drop        — OR (cuts accumulate; healing is the window's end),
  extra_delay — additive,
  nic_scale   — multiplicative.

Windows are maximal intervals between the union of all primitives' tick
edges, so every table row is constant over its window by construction.
Diagonal (self) links are never dropped or delayed: protocols rely on
self-delivery, and a box that cannot talk to itself is a ``Crash``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.smr import SMRConfig

# "leader" = replica 0 (the leader of view 0 under the rotating v % n rule);
# "minority" = the first f = (n-1)//2 replicas; "random-minority" (only for
# TargetedDelay) re-picks a seeded random minority per repick window.
Targets = Union[str, Sequence[int]]

Tables = Dict[str, np.ndarray]


def resolve_targets(targets: Targets, n: int) -> np.ndarray:
    """[n] bool mask for a static target selector."""
    mask = np.zeros((n,), np.bool_)
    if isinstance(targets, str):
        if targets == "all":
            mask[:] = True
        elif targets == "leader":
            mask[0] = True
        elif targets == "minority":
            mask[: (n - 1) // 2] = True
        else:
            raise ValueError(f"unknown target selector {targets!r}")
    else:
        mask[np.asarray(list(targets), np.int64)] = True
    return mask


def _tick(cfg: SMRConfig, seconds: float, n_ticks: int) -> int:
    """First tick at or after a point in time, clipped to the sim. The
    boundary is computed in float32 — the simulator's native time precision
    (and what the seed-era ``t < crash_tick`` compare used, which keeps
    these primitives bitwise-exact against the seed-era fault model)."""
    if not math.isfinite(seconds):
        return n_ticks
    ticks = np.float32(seconds * 1000.0 / cfg.tick_ms)
    return min(n_ticks, max(0, int(np.ceil(ticks))))


def _covered(win_start: np.ndarray, t0: int, t1: int) -> np.ndarray:
    """[W] bool — windows whose (constant) span lies inside [t0, t1)."""
    return (win_start >= t0) & (win_start < t1)


def _offdiag(n: int) -> np.ndarray:
    return ~np.eye(n, dtype=np.bool_)


@dataclass(frozen=True)
class Scenario:
    """A named, ordered composition of adversary primitives."""
    name: str = "baseline"
    events: Tuple = ()


@dataclass(frozen=True)
class Crash:
    """Targets are down over [start_s, end_s) — an interval, not a one-way
    trip; omit end_s for a permanent crash.

    Semantics: a down replica neither sends nor acts, but its channels keep
    absorbing delivered state (netsim gates *actions* on alive, matching
    the seed model). Recovery therefore models a paused-then-resumed
    process that kept its in-memory monotone state — not a disk-wiped
    rebuild; there is no post-recovery catch-up cost beyond re-joining the
    protocol."""
    start_s: float
    targets: Targets = "leader"
    end_s: float = math.inf

    def edges(self, cfg: SMRConfig, n_ticks: int):
        return (_tick(cfg, self.start_s, n_ticks),
                _tick(cfg, self.end_s, n_ticks))

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        w = _covered(win_start, _tick(cfg, self.start_s, n_ticks),
                     _tick(cfg, self.end_s, n_ticks))
        tab["alive"][np.ix_(w, resolve_targets(self.targets,
                                               tab["alive"].shape[1]))] = False


@dataclass(frozen=True)
class Recover:
    """Targets are up from at_s on (overrides any earlier Crash)."""
    at_s: float
    targets: Targets = "all"

    def edges(self, cfg: SMRConfig, n_ticks: int):
        return (_tick(cfg, self.at_s, n_ticks),)

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        w = win_start >= _tick(cfg, self.at_s, n_ticks)
        tab["alive"][np.ix_(w, resolve_targets(self.targets,
                                               tab["alive"].shape[1]))] = True


@dataclass(frozen=True)
class Partition:
    """Drop every link between replicas of *different* groups over
    [start_s, end_s); replicas in no group keep all their links. Heals when
    the window ends (in-flight messages are not retroactively dropped)."""
    start_s: float
    end_s: float
    groups: Tuple[Tuple[int, ...], ...]

    def edges(self, cfg: SMRConfig, n_ticks: int):
        return (_tick(cfg, self.start_s, n_ticks),
                _tick(cfg, self.end_s, n_ticks))

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        n = tab["alive"].shape[1]
        member = np.full((n,), -1, np.int64)
        for gi, g in enumerate(self.groups):
            member[np.asarray(list(g), np.int64)] = gi
        cut = ((member[:, None] >= 0) & (member[None, :] >= 0)
               & (member[:, None] != member[None, :]))
        w = _covered(win_start, _tick(cfg, self.start_s, n_ticks),
                     _tick(cfg, self.end_s, n_ticks))
        tab["drop"][w] |= cut[None]


@dataclass(frozen=True)
class RegionOutage:
    """Correlated regional event over [start_s, end_s): the region's
    replicas are down AND the surviving WAN picks up reroute turbulence
    (delay_ms extra one-way delay on every link)."""
    start_s: float
    end_s: float
    regions: Targets = (2,)
    delay_ms: float = 50.0

    def edges(self, cfg: SMRConfig, n_ticks: int):
        return (_tick(cfg, self.start_s, n_ticks),
                _tick(cfg, self.end_s, n_ticks))

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        n = tab["alive"].shape[1]
        w = _covered(win_start, _tick(cfg, self.start_s, n_ticks),
                     _tick(cfg, self.end_s, n_ticks))
        tab["alive"][np.ix_(w, resolve_targets(self.regions, n))] = False
        tab["extra_delay"][w] += (np.float32(self.delay_ms / cfg.tick_ms)
                                  * _offdiag(n)[None])


@dataclass(frozen=True)
class GrayFailure:
    """Stochastic per-link degradation over [start_s, end_s): every
    redraw_s the adversary re-draws, per directed link, a uniform extra
    delay in [0, jitter_ms] and a Bernoulli(loss) drop. Draws come from a
    seeded per-redraw-window RandomState, so the lowered tables are a pure
    function of (cfg, primitive)."""
    start_s: float
    end_s: float
    loss: float = 0.05
    jitter_ms: float = 20.0
    redraw_s: float = 0.1
    seed: int = 0

    def _redraw_ticks(self, cfg: SMRConfig) -> int:
        return max(1, int(self.redraw_s * 1000.0 / cfg.tick_ms))

    def edges(self, cfg: SMRConfig, n_ticks: int):
        t0 = _tick(cfg, self.start_s, n_ticks)
        t1 = _tick(cfg, self.end_s, n_ticks)
        return tuple(range(t0, t1, self._redraw_ticks(cfg))) + (t1,)

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        n = tab["alive"].shape[1]
        t0 = _tick(cfg, self.start_s, n_ticks)
        t1 = _tick(cfg, self.end_s, n_ticks)
        off = _offdiag(n)
        for w in np.flatnonzero(_covered(win_start, t0, t1)):
            k = int(win_start[w] - t0) // self._redraw_ticks(cfg)
            rng = np.random.RandomState(
                (self.seed * 1_000_003 + 7919 * k) % (2**32 - 1))
            jit = rng.uniform(0.0, self.jitter_ms, (n, n)) / cfg.tick_ms
            lost = rng.random_sample((n, n)) < self.loss
            tab["extra_delay"][w] += (jit * off).astype(np.float32)
            tab["drop"][w] |= lost & off


@dataclass(frozen=True)
class TargetedDelay:
    """Generalized §5.5 DDoS: every link touching an attacked replica gains
    delay_ms each way over [start_s, end_s). Attack a fixed set ("leader",
    "minority", explicit indices) or, with targets="random-minority" and a
    repick_s, a seeded random minority re-picked per repick window — the
    exact seed-era DDoS fault-schedule attack."""
    delay_ms: float = 800.0
    targets: Targets = "minority"
    start_s: float = 0.0
    end_s: float = math.inf
    repick_s: Optional[float] = None
    seed: int = 7

    def _repick_ticks(self, cfg: SMRConfig) -> int:
        assert self.repick_s is not None
        return max(1, int(self.repick_s * 1000.0 / cfg.tick_ms))

    def edges(self, cfg: SMRConfig, n_ticks: int):
        t0 = _tick(cfg, self.start_s, n_ticks)
        t1 = _tick(cfg, self.end_s, n_ticks)
        if self.repick_s is None:
            return (t0, t1)
        return tuple(range(t0, t1, self._repick_ticks(cfg))) + (t1,)

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        n = tab["alive"].shape[1]
        t0 = _tick(cfg, self.start_s, n_ticks)
        t1 = _tick(cfg, self.end_s, n_ticks)
        ws = np.flatnonzero(_covered(win_start, t0, t1))
        delay = np.float32(self.delay_ms / cfg.tick_ms)
        if self.targets == "random-minority":
            if self.repick_s is None:
                raise ValueError("random-minority requires repick_s")
            repick = self._repick_ticks(cfg)
            # one sequential RandomState stream, row k = k-th repick window
            # (matches the seed-era pre-generated attacked-minority table)
            n_draws = ((int(win_start[ws[-1]]) - t0) // repick + 1
                       if len(ws) else 0)
            rng = np.random.RandomState(self.seed)
            f = (n - 1) // 2
            att_k = [rng.choice(n, size=f, replace=False)
                     for _ in range(n_draws)]
            for w in ws:
                att = np.zeros((n,), np.bool_)
                att[att_k[(int(win_start[w]) - t0) // repick]] = True
                tab["extra_delay"][w] += (att[:, None] | att[None, :]) * delay
        else:
            att = resolve_targets(self.targets, n)
            tab["extra_delay"][ws] += ((att[:, None] | att[None, :])
                                       * delay)[None]


@dataclass(frozen=True)
class BandwidthThrottle:
    """Scale the targets' NIC egress rate (bytes_per_tick) by ``scale``
    over [start_s, end_s)."""
    start_s: float
    end_s: float
    scale: float = 0.1
    targets: Targets = "all"

    def edges(self, cfg: SMRConfig, n_ticks: int):
        return (_tick(cfg, self.start_s, n_ticks),
                _tick(cfg, self.end_s, n_ticks))

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        w = _covered(win_start, _tick(cfg, self.start_s, n_ticks),
                     _tick(cfg, self.end_s, n_ticks))
        mask = resolve_targets(self.targets, tab["alive"].shape[1])
        tab["nic_scale"][np.ix_(w, mask)] *= np.float32(self.scale)
