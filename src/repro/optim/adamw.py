"""AdamW with optional block-quantized int8 moments (memory-critical for the
>=100B MoE archs: 2 bytes/param of optimizer state instead of 8) and an
error-feedback int8 gradient compressor for the DP all-reduce.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256          # DP gradient-compression block (flat)
QUANT_MIN_SIZE = 1 << 22   # quantize moments only for leaves >= 4M params


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_state: bool = False      # int8 m/v (row-scaled)
    warmup_steps: int = 100


def _q8_rows(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Quantize along the param's own last dim (per-row absmax scales) —
    the int8 state keeps the param's SHAPE, so it inherits the param's
    sharding and the update math never regathers moments (EXPERIMENTS.md
    §Perf, arctic iteration 4: misaligned flat blocks forced XLA to
    all-gather ~6 TB of dequantized fp32 moments per step)."""
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    q = jnp.round(x / jnp.maximum(s, 1e-12)).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def _dq8_rows(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def _quantizable(p) -> bool:
    return p.size >= QUANT_MIN_SIZE and p.ndim >= 1


def init_opt_state(cfg: AdamWConfig, params) -> Dict[str, Any]:
    def zeros_like_q(p):
        if cfg.quantized_state and _quantizable(p):
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "s": jnp.zeros(p.shape[:-1] + (1,), jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_q, params),
        "v": jax.tree.map(zeros_like_q, params),
    }


def _load(cfg: AdamWConfig, slot, p):
    if isinstance(slot, dict):
        return _dq8_rows(slot["q"], slot["s"])
    return slot


def _store(cfg: AdamWConfig, val, like):
    if isinstance(like, dict):
        q, s = _q8_rows(val)
        return {"q": q, "s": s}
    return val


def global_norm(grads) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def apply_updates(cfg: AdamWConfig, params, grads, opt_state
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    warm = jnp.minimum(1.0, step.astype(jnp.float32) / cfg.warmup_steps)
    lr = cfg.lr * warm
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m0, v0 in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _load(cfg, m0, p) + (1 - cfg.b1) * g
        v = cfg.b2 * _load(cfg, v0, p) + (1 - cfg.b2) * g * g
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(_store(cfg, m, m0))
        new_v.append(_store(cfg, v, v0))
    params = jax.tree.unflatten(treedef, new_p)
    opt_state = {"step": step, "m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v)}
    return params, opt_state, {"grad_norm": gn, "lr": lr}


# ---- int8 error-feedback gradient compression (DP axis) --------------------

def _q8_flat(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8_flat(q, scale, shape, size):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_grad(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (int8 q, scales, new_error). all-reduce q (cheap), correct
    locally with error feedback next step."""
    corrected = g.astype(jnp.float32) + err
    q, s = _q8_flat(corrected)
    deq = _dq8_flat(q, s, g.shape, g.size)
    return q, s, corrected - deq


def decompress_grad(q: jax.Array, s: jax.Array, shape, size: int) -> jax.Array:
    return _dq8_flat(q, s, shape, size)
