"""Pure-jnp oracle for the flash attention kernel (causal, GQA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: [B, Sq, H, D]; k, v: [B, Sk, Kh, D]; H % Kh == 0. fp32 softmax."""
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    qg = q.reshape(b, sq, kh, h // kh, d)
    logits = jnp.einsum("bqkgd,btkd->bkgqt", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, d)
