"""Unit tests for ``repro.distributed.hlo_analysis`` on a fixture HLO
module (pure text — no jax): the loop-aware cost model, the async
``-start``/``-done`` opcode handling, parser hardening (tuple results,
nested tuples, fusion calls, while bodies with ``known_trip_count``),
and the program-audit queries behind ``repro.analysis.hlo_lint``.

The fixture is a hand-written module with one scan-shaped while loop
(trip count 10) containing an async all-gather and a python-callback
custom-call, plus entry-level dots (one direct, one fused), an f64
convert, and a host-buffer custom-call OUTSIDE the loop — so every
audit query has both a positive and a negative case.
"""
from pathlib import Path

from repro.distributed import hlo_analysis as hlo

FIXTURE = (Path(__file__).parent / "fixtures" / "hlo" /
           "audit_fixture.hlo").read_text()

# fixture constants
TRIPS = 10
AG_BYTES = 128 * 4            # f32[128] all-gather result
DOT_FLOPS = 2 * (8 * 32) * 16  # f32[8,32] dot with K=16


def test_parse_module_structure():
    comps, entry = hlo._parse_module(FIXTURE)
    assert entry == "main"
    assert set(comps) == {"fused_dot", "body", "cond", "main"}
    names = {op.name: op for op in comps["main"]}
    # tuple-shaped results parse (while carry + a nested tuple)
    assert names["w"].opcode == "while"
    assert names["w"].shape == "(s32[], f32[64])"
    assert names["nt"].opcode == "tuple"
    assert names["nt"].shape == "((f32[2], s32[]), f32[4])"
    # while op exposes both computations as callees
    assert set(names["w"].callees()) == {"cond", "body"}
    # fusion call target
    assert names["fu"].callees() == ["fused_dot"]


def test_base_opcode_strips_async_suffix_only():
    # str.rstrip("-start") strips a CHARACTER SET and would eat
    # "all-gather-start" down to "all-gathe" — the old bug this pins
    assert hlo._base_opcode("all-gather-start") == "all-gather"
    assert hlo._base_opcode("all-gather-done") == "all-gather"
    assert hlo._base_opcode("reduce-scatter-start") == "reduce-scatter"
    assert hlo._base_opcode("all-reduce") == "all-reduce"
    assert hlo._base_opcode("all-to-all") == "all-to-all"


def test_dot_flops():
    comps, _ = hlo._parse_module(FIXTURE)
    shapes = {op.name: op.shape
              for ops in comps.values() for op in ops}
    (dot,) = [op for op in comps["main"] if op.opcode == "dot"]
    assert hlo._dot_flops(dot, shapes) == DOT_FLOPS


def test_module_cost_counts_fused_and_direct_dots():
    cost = hlo.module_cost(FIXTURE)
    # entry dot + the dot inside the fusion body; loop has no dots
    assert cost["flops"] == 2 * DOT_FLOPS
    assert cost["bytes"] > 0


def test_collective_stats_are_loop_scaled():
    stats = hlo.collective_stats(FIXTURE)
    # the async all-gather runs once per trip; -done must not double it
    # (and must not vanish, as under the rstrip bug)
    assert stats["all-gather"]["count"] == TRIPS
    assert stats["all-gather"]["bytes"] == AG_BYTES * TRIPS
    for kind in ("all-reduce", "reduce-scatter", "all-to-all",
                 "collective-permute"):
        assert stats[kind]["count"] == 0


def test_dtype_op_counts():
    counts = hlo.dtype_op_counts(FIXTURE)
    assert counts["f64"] == 1          # the convert — H1's positive case
    assert counts["f32"] > 10
    assert "bf16" not in counts


def test_while_stats():
    (w,) = hlo.while_stats(FIXTURE)
    assert w["comp"] == "main" and w["outer"] is True
    assert w["body"] == "body" and w["trip_count"] == TRIPS


def test_loop_computations():
    assert hlo.loop_computations(FIXTURE) == {"cond", "body"}


def test_host_transfer_ops_tag_loop_membership():
    ops = {t["name"]: t for t in hlo.host_transfer_ops(FIXTURE)}
    assert set(ops) == {"cb", "hcb"}
    assert ops["cb"]["in_loop"] is True      # H2's positive case
    assert ops["hcb"]["in_loop"] is False    # post-scan host pull: legal
