"""Tracelint layer 2: HLO program auditor.

Lowers each scan protocol's CANONICAL sweep program (the exact program
the fig suites execute: ``experiment._lower(canonical=True)`` at the
default trace/monitor-off config) and statically asserts over the
optimized HLO via ``repro.distributed.hlo_analysis``:

  H1 hlo-f64            zero f64 ops module-wide (device programs are
                        f32; f64 creep doubles ring HBM and breaks the
                        bitwise-artifact pins)
  H2 hlo-host-transfer  zero infeed/outfeed/send/recv/host-callback
                        custom-calls inside the scan loop — the sim must
                        stay device-resident for all n_ticks
  H3 hlo-while          exactly one outer while with
                        ``known_trip_count == n_ticks``: the scan fused
                        into a single loop, not unrolled or split (the
                        small post-scan metric-extraction loops XLA
                        emits for sorts/quantiles are not scans and are
                        exempt)
  H4 hlo-signature      program-signature stability: every point of a
                        scenario x rate grid (and the combined grid)
                        lowers to ONE ``ProgramSignature`` per static
                        workload mode — the recompile-trigger audit

Compiling through ``jax.jit(...).lower().compile()`` consults the
persistent compile cache, so on a warm ``.jax_cache`` (CI restores it;
any prior fig-suite run populates it) the audit costs tracing only.

The analytic protocols (epaxos, rabia) have no device program; they are
recorded as vacuously clean so the verdict honestly covers all six
protocols.

The verdict dict is shaped like an ``obs/monitor.py`` verdict
(``ok`` / ``violations`` / ``level`` / ``points``) so it rides the
``BENCH_history.jsonl`` ledger and gates through ``history.compare``
exactly like runtime monitor violations.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import Finding, Report, RULE_KEYS

AUDIT_SCENARIOS = (None, "leader-crash-recover", "symmetric-partition")
AUDIT_RATES = (50_000.0, 300_000.0)
AUDIT_WORKLOAD = "onoff-burst"   # windowed but canonical-width (8 rows)


def _emit(report: Optional[Report], rule: str, where: str,
          message: str) -> None:
    if report is not None:
        report.findings.append(Finding(
            rule=rule, key=RULE_KEYS[rule], file=where, line=0, col=0,
            severity="error", message=message))


def _grid_signatures(cfg, spec_cls, lower, scenario_get, workload_get,
                     sim_seconds: float, workload: Optional[str]):
    """Signatures of every single-point lowering across the audit grid,
    plus the combined-grid lowering (all host-side numpy: no compiles)."""
    n = cfg.n_replicas
    scens = [scenario_get(s, sim_seconds, n) if s else None
             for s in AUDIT_SCENARIOS]
    wl = workload_get(workload, sim_seconds, n) if workload else None
    sigs = {}
    for scen, name in zip(scens, AUDIT_SCENARIOS):
        for rate in AUDIT_RATES:
            spec = spec_cls(rates=(rate,), scenarios=(scen,),
                            workloads=(wl,))
            sig = lower(cfg, spec)[-1]
            sigs.setdefault(sig, []).append(
                f"{name or 'baseline'}@{rate:.0f}")
    combined = spec_cls(rates=AUDIT_RATES, scenarios=tuple(scens),
                        workloads=(wl,))
    sigs.setdefault(lower(cfg, combined)[-1], []).append("combined-grid")
    return sigs


def audit(protocols=None, sim_seconds: float = 2.0,
          report: Optional[Report] = None) -> Dict:
    """Run the full H1–H4 audit; returns the monitor-shaped verdict and
    (optionally) appends per-program findings to ``report``."""
    from repro.configs.smr import SMRConfig
    from repro.core import compile_cache, experiment, harness
    from repro.distributed import hlo_analysis as hlo
    from repro.scenarios import library as scenario_library
    from repro.workloads import library as workload_library

    compile_cache.enable()
    if protocols is None:
        protocols = harness.SCAN_PROTOCOLS + experiment.ANALYTIC_PROTOCOLS
    cfg = SMRConfig(sim_seconds=sim_seconds)
    t0 = time.perf_counter()
    per: Dict[str, Dict] = {}
    tot = {"f64_ops": 0, "host_transfer_in_loop": 0, "outer_while": 0,
           "signature_drift": 0}

    for proto in protocols:
        if proto in experiment.ANALYTIC_PROTOCOLS:
            per[proto] = {"program": None,
                          "note": "host analytic model — no device "
                                  "program; vacuously clean"}
            continue
        spec = experiment.SweepSpec(rates=(AUDIT_RATES[-1],))
        _, cfg2, mode, env_b, wl_b, rate_b, seed_b, sig = \
            experiment._lower(cfg, spec, canonical=True)
        text = experiment._sweep_compiled.lower(
            proto, cfg2, mode, env_b, wl_b, rate_b, seed_b
        ).compile().as_text()

        from repro.core import netsim
        n_ticks = netsim.sim_ticks(cfg2)
        f64 = hlo.dtype_op_counts(text).get("f64", 0)
        transfers = hlo.host_transfer_ops(text)
        in_loop = [t for t in transfers if t["in_loop"]]
        whiles = hlo.while_stats(text)
        # the scan loop: outer and trip_count == n_ticks (XLA also emits
        # small outer loops for the post-scan sort/quantile extraction)
        outer = [w for w in whiles
                 if w["outer"] and w["trip_count"] == n_ticks]
        where = f"<hlo:{proto}>"
        if f64:
            _emit(report, "H1", where,
                  f"{f64} f64 op(s) in the canonical program — device "
                  "buffers must stay f32")
        if in_loop:
            ops = ", ".join(f"{t['opcode']}:{t['name']}"
                            for t in in_loop[:4])
            _emit(report, "H2", where,
                  f"{len(in_loop)} host transfer(s) inside the scan "
                  f"loop ({ops}) — the sim must stay device-resident")
        if len(outer) != 1:
            _emit(report, "H3", where,
                  f"{len(outer)} outer while loop(s) with trip_count == "
                  f"n_ticks ({n_ticks}) — expected exactly 1: the scan, "
                  "fused, not unrolled or split")
        tot["f64_ops"] += f64
        tot["host_transfer_in_loop"] += len(in_loop)
        tot["outer_while"] += abs(len(outer) - 1)
        per[proto] = {
            "signature": repr(sig),
            "f64_ops": f64,
            "host_transfers": len(transfers),
            "host_transfers_in_loop": len(in_loop),
            "whiles": len(whiles),
            "scan_whiles": len(outer),
            "trip_count": n_ticks if outer else None,
        }

    # H4 — recompile-trigger audit: protocol-independent shape axes
    drift: Dict[str, List[str]] = {}
    for wl_name, tag in ((None, "trivial"), (AUDIT_WORKLOAD, "windowed")):
        sigs = _grid_signatures(cfg, experiment.SweepSpec,
                                experiment._lower, scenario_library.get,
                                workload_library.get, sim_seconds, wl_name)
        if len(sigs) != 1:
            detail = "; ".join(f"{s} <- {', '.join(pts)}"
                               for s, pts in sigs.items())
            _emit(report, "H4", f"<hlo:grid:{tag}>",
                  f"{len(sigs)} distinct program signatures across the "
                  f"{tag} scenario x rate grid (expected 1): {detail}")
            tot["signature_drift"] += len(sigs) - 1
        drift[tag] = {repr(s): pts for s, pts in sigs.items()}

    verdict = {
        "ok": not any(tot.values()),
        "violations": {k: v for k, v in tot.items() if v},
        "level": "hlo",
        "points": len(per),
        "protocols": per,
        "signatures": drift,
        "wall_s": round(time.perf_counter() - t0, 3),
        "sim_seconds": sim_seconds,
    }
    return verdict


def format_verdict(v: Dict) -> str:
    head = "hlo-audit OK" if v["ok"] else \
        f"hlo-audit VIOLATIONS {v['violations']}"
    lines = [f"{head} ({v['points']} protocols, "
             f"{v['wall_s']:.1f}s, sim {v['sim_seconds']:.1f}s)"]
    for proto, d in v["protocols"].items():
        if d.get("program", "x") is None:
            lines.append(f"  {proto:18s} {d['note']}")
        else:
            lines.append(
                f"  {proto:18s} f64={d['f64_ops']} "
                f"host_xfer_in_loop={d['host_transfers_in_loop']} "
                f"scan_while={d['scan_whiles']} "
                f"trip={d['trip_count']}")
    for tag, sigs in v["signatures"].items():
        lines.append(f"  grid[{tag}]: {len(sigs)} signature(s)")
    return "\n".join(lines)


def append_history(path, verdict: Dict, quick: bool = True,
                   analysis_counts: Optional[Dict[str, int]] = None) \
        -> None:
    """Land the audit verdict in the BENCH_history.jsonl ledger as an
    ``hlo-audit`` suite entry — regressions then gate through
    ``history.compare`` exactly like runtime monitor violations."""
    from pathlib import Path

    from repro.obs import history
    suite = {"wall_s": verdict["wall_s"], "monitor": verdict}
    if analysis_counts is not None:
        suite["analysis"] = dict(analysis_counts)
    repo = Path(path).resolve().parent
    entry = history.make_entry({"hlo-audit": suite}, quick=quick,
                               git_sha=history.git_sha(repo),
                               timestamp=time.time())
    history.append(path, entry)
