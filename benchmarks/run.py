# One function per paper table/figure. Prints ``name,us_per_call,derived``.
"""Benchmark driver:
  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig6,roofline,...]

Figure suites dispatch through the batched experiment engine
(repro.core.experiment): each protocol's whole rate grid compiles once and
runs as a single vmapped program; the per-suite stderr line reports
wall-clock and the cumulative jit-trace count.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks import figures  # noqa: E402
from benchmarks import roofline  # noqa: E402
from benchmarks.bench_kernels import bench as kernel_bench  # noqa: E402
from repro.core import experiment  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter sims (2s instead of 4s)")
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()
    sim_s = 2.0 if args.quick else 4.0
    only = set(args.only.split(",")) if args.only else None

    figures.ART.mkdir(parents=True, exist_ok=True)
    suites = {
        "fig6": lambda: figures.fig6_throughput_latency(sim_s),
        "fig7": lambda: figures.fig7_crash(sim_s),
        "fig8": lambda: figures.fig8_ddos(sim_s),
        "fig9": lambda: figures.fig9_scalability(max(sim_s - 1, 2.0)),
        "robustness": lambda: figures.robustness(sim_s),
        "workload-matrix": lambda: figures.workload_matrix(sim_s),
        "paper": figures.paper_comparison,
        "kernels": kernel_bench,
        "roofline_single": lambda: roofline.rows("single"),
        "roofline_multi": lambda: roofline.rows("multi"),
    }
    if only:
        unknown = only - suites.keys()
        if unknown:
            sys.exit(f"unknown suite(s): {', '.join(sorted(unknown))}; "
                     f"valid: {', '.join(suites)}")
    print("name,us_per_call,derived")
    errored = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            errored.append(name)
        traces = sum(experiment.trace_counts().values())
        print(f"# {name} done in {time.time() - t0:.0f}s "
              f"(sweep traces so far: {traces})", file=sys.stderr)
    roofline.main()
    if errored:
        sys.exit(f"suite(s) errored: {', '.join(errored)}")


if __name__ == "__main__":
    main()
