"""End-to-end behaviour tests: training converges, survives pod crash with
elastic replan, checkpoint-resume is exact, serving decodes; HLO cost model
correctness; dry-run machinery on a small host-device mesh (subprocess)."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def test_train_loss_decreases():
    from repro.launch.train import train
    out = train("smollm-135m", steps=40, batch=4, seq=32, verbose=False)
    assert out["losses"][-1] < out["losses"][0] - 0.1


def test_train_survives_pod_crash_elastic():
    from repro.launch.train import train
    out = train("smollm-135m", steps=20, batch=6, seq=16, n_pods=3,
                crash_pod_at=8, verbose=False)
    assert len(out["losses"]) == 20                 # every step committed
    assert np.isfinite(out["losses"]).all()
    # the surviving controllers kept committing after the crash
    assert out["commits"][0] > 8


def test_checkpoint_resume_exact(tmp_path):
    from repro.launch.train import train
    a = train("smollm-135m", steps=20, batch=2, seq=16,
              ckpt_dir=str(tmp_path / "ck"), ckpt_every=10, verbose=False)
    # fresh run restores at step 20 and must produce no further steps
    b = train("smollm-135m", steps=20, batch=2, seq=16,
              ckpt_dir=str(tmp_path / "ck"), ckpt_every=10, verbose=False)
    assert b["losses"] == []                        # resumed at completion
    for la, lb in zip(jax.tree.leaves(a["params"]),
                      jax.tree.leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_serve_decodes():
    from repro.launch.serve import serve
    out = serve("musicgen-medium", batch=2, prompt_len=4, gen=6,
                verbose=False)
    assert out["tokens"].shape[0] == 2
    assert out["tokens"].shape[1] >= 6


def test_hlo_cost_model_counts_scan_trips():
    from repro.distributed.hlo_analysis import module_cost

    def f(a, b):
        def body(x, _):
            return x @ b, None
        out, _ = jax.lax.scan(body, a, None, length=10)
        return out

    s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = jax.jit(f).lower(s, s).compile().as_text()
    mc = module_cost(txt)
    expect = 10 * 2 * 256 ** 3
    assert abs(mc["flops"] - expect) / expect < 0.05


def test_hlo_collective_parsing_fixture():
    from repro.distributed.hlo_analysis import collective_stats
    fake = """
HloModule m

ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0), to_apply=%add
  ROOT %ag = f32[32,128]{1,0} all-gather(%ar), dimensions={0}
}
"""
    st_ = collective_stats(fake)
    assert st_["all-reduce"]["count"] == 1
    assert st_["all-reduce"]["bytes"] == 16 * 128 * 4
    assert st_["all-gather"]["bytes"] == 32 * 128 * 4


DRYRUN_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from functools import partial
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.distributed.sharding import batch_shardings, param_shardings
from repro.distributed.steps import make_train_step
from repro.models import CallConfig, init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.launch.dryrun import _opt_shardings
import numpy as np

cfg = get_config("qwen3-14b").reduced()
shape = ShapeConfig("t", "train", 32, 8)
call = CallConfig(compute_dtype=jnp.float32, attention_impl="dense", remat=False)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = init_params(cfg, jax.random.PRNGKey(0))
p_sh = param_shardings(cfg, mesh, jax.eval_shape(lambda: params))
opt = AdamWConfig(lr=1e-3, warmup_steps=1)
opt_state = init_opt_state(opt, params)
o_sh = _opt_shardings(mesh, jax.eval_shape(lambda: opt_state), p_sh)
from repro.data.pipeline import DataConfig, global_batch
batch = global_batch(cfg, shape, DataConfig(), 0)
b_sh = batch_shardings(cfg, shape, mesh, jax.eval_shape(lambda: batch))
step = jax.jit(make_train_step(cfg, call, opt),
               in_shardings=(p_sh, o_sh, b_sh), out_shardings=(p_sh, o_sh, None))
with mesh:
    # distributed result == single-device result
    p2, o2, m = step(jax.device_put(params, p_sh),
                     jax.device_put(opt_state, o_sh),
                     jax.device_put(batch, b_sh))
single = jax.jit(make_train_step(cfg, call, opt))
p1, o1, m1 = single(params, opt_state, batch)
err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
          zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
loss_err = abs(float(m["loss"]) - float(m1["loss"]))
assert err < 5e-4, err
assert loss_err < 5e-4, loss_err
print("OK", err, loss_err)
"""


def test_sharded_step_matches_single_device():
    """The SPMD-sharded train step computes the same update as the
    single-device step (8 host devices, subprocess isolation)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", DRYRUN_SNIPPET], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
