"""Host-side view of a compiled workload for the analytic baselines.

The EPaxos/Rabia models (core/epaxos.py, core/rabia.py) have no tick loop;
they integrate batch streams on the host. ``host_rate`` gives them the
same compiled rate table the simulator reads — as a plain
``mult_at(t_ms) -> [n]`` lookup — so the workload matrix covers all six
protocols instead of silently skipping the two analytic ones.

For the trivial baseline ``mult_at`` is None and callers keep their exact
constant-rate code path (byte-identical fig 6/8 artifacts).

Closed-loop workloads have no open offered rate; ``closed_equilibrium_rate``
maps the sweep rate (= client population via Little's law) to the
equilibrium arrival rate clients sustain once the model's own latency is
fed back: rate_eff = rate x think / (think + median latency), additionally
bounded by the per-origin outstanding cap (throughput <= n x cap / latency).
The models run twice — once open to measure latency, once at equilibrium.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.configs.smr import SMRConfig
from repro.workloads.compile import as_workload, is_trivial, lower


class TableRate:
    """Host-side lookup over a compiled rate table: ``at(t_ms)`` is the
    per-origin multiplier row, ``next_change_ms(t_ms)`` the time the row
    next changes (sim end if never) — so stream generators can skip a
    zero-rate window instead of dividing by ~0 and jumping past the run."""

    def __init__(self, cfg: SMRConfig, tab):
        self._cfg = cfg
        self._win_start = tab["win_start"]
        self._win_of_tick = tab["win_of_tick"]
        self._rate_of = tab["rate_of"]

    def at(self, t_ms: float) -> np.ndarray:
        tick = min(max(int(t_ms / self._cfg.tick_ms), 0),
                   len(self._win_of_tick) - 1)
        return self._rate_of[self._win_of_tick[tick]]

    def next_change_ms(self, t_ms: float) -> float:
        sim_ms = len(self._win_of_tick) * self._cfg.tick_ms
        tick = int(t_ms / self._cfg.tick_ms)
        nxt = np.searchsorted(self._win_start, tick, side="right")
        if nxt >= len(self._win_start):
            return sim_ms
        return float(self._win_start[nxt]) * self._cfg.tick_ms


def host_rate(cfg: SMRConfig, workload
              ) -> Tuple[Optional[TableRate], Optional[dict]]:
    """Returns (rate, closed): ``rate`` is a TableRate over the compiled
    table (None for the trivial baseline — callers keep their exact
    constant-rate path), ``closed`` is None or {"think_ms", "cap"}."""
    tab = lower(cfg, as_workload(workload))
    closed = None
    if float(tab["closed"]) > 0:
        closed = {"think_ms": float(tab["think_ticks"]) * cfg.tick_ms,
                  "cap": float(tab["cap"])}
    if is_trivial(tab):
        return None, None
    return TableRate(cfg, tab), closed


def closed_equilibrium_rate(rate_tx_s: float, closed: dict,
                            median_ms: float, n_origins: int) -> float:
    """Little's-law equilibrium arrival rate for a closed-loop pool whose
    open-loop latency measurement came back ``median_ms``."""
    think = closed["think_ms"]
    lat = median_ms if np.isfinite(median_ms) else think
    rate = rate_tx_s * think / (think + max(lat, 0.0))
    cap_bound = n_origins * closed["cap"] * 1000.0 / max(lat, 1e-9)
    return float(min(rate, cap_bound))
