"""Jit'd wrapper for the flash-decoding kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas


@partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *, bs: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """q: [B, H, D]; k, v: [B, Kh, S, D]; kv_len: [B] -> [B, H, D]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    s = k.shape[2]
    while s % bs and bs > 1:
        bs //= 2
    return decode_attention_pallas(q, k, v, kv_len, bs=bs,
                                   interpret=interpret)
