"""xlstm-1.3b — sLSTM + mLSTM blocks (7:1), no FFN.  [arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab=50304,
    ssm=SSMConfig(kind="xlstm", slstm_every=8, chunk=128),
    notes="mLSTM matrix-memory linear attention; sLSTM every 8th layer; d_ff=0",
)
