"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell on
the production mesh, print memory_analysis + cost_analysis, and record the
roofline terms. This is the proof that the distribution config is coherent
without real hardware — failures here are bugs in the framework.

The first two executable lines pin 512 placeholder devices BEFORE any jax
import (jax locks the device count on first init). This is deliberately NOT
set globally — smoke tests and benches must see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
Artifacts: benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, iter_cells, param_count
from repro.distributed import hlo_analysis
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        param_shardings)
from repro.distributed.steps import (cache_specs, input_specs,
                                     make_serve_step, make_train_step)
from repro.launch.mesh import make_production_mesh
from repro.models import CallConfig, init_params
from repro.optim.adamw import AdamWConfig, init_opt_state

ART = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

QUANTIZED_STATE_THRESHOLD = 100e9   # int8 moments for >=100B-param archs


def _opt_shardings(mesh, opt_shape, p_shardings):
    """Moments follow the param sharding exactly; quantized slots keep the
    param's shape (q) / row-scale shape (s) so nothing regathers."""

    def one(path, leaf):
        names = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        if names[0] == "step":
            return NamedSharding(mesh, P())
        is_q = names[-1] in ("q", "s")
        lookup = names[1:-1] if is_q else names[1:]
        sub = p_shardings
        for nm in lookup:
            sub = sub[nm] if isinstance(sub, dict) else sub[int(nm)]
        if not is_q:
            return sub
        spec = list(sub.spec) + [None] * (leaf.ndim - len(sub.spec))
        if names[-1] == "s":
            spec[-1] = None                   # row scales: last dim is 1
        return NamedSharding(mesh, P(*spec[:leaf.ndim]))

    return jax.tree_util.tree_map_with_path(one, opt_shape)


def _analyze(compiled, n_devices: int, model_params: int,
             active_params: int, tokens: int):
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    mc = hlo_analysis.module_cost(hlo)   # loop-aware (known_trip_count)
    flops = float(mc["flops"])
    byt = float(mc["bytes"])
    coll = mc["collectives"]
    coll_bytes = float(mc["collective_bytes"])
    terms = hlo_analysis.roofline_terms(flops, byt, coll_bytes)
    model_flops = 6.0 * active_params * tokens
    out = {
        "devices": n_devices,
        "flops_per_device": flops,
        "bytes_per_device": byt,
        "collective_bytes_per_device": coll_bytes,
        "collectives": coll,
        "xla_cost_analysis": {k: float(v) for k, v in (cost or {}).items()
                              if k in ("flops", "bytes accessed")},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / n_devices,
        "useful_flop_ratio": (model_flops / n_devices) / flops if flops else 0.0,
        **terms,
    }
    return out, mem, cost


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             call: CallConfig | None = None, verbose: bool = True,
             policy: str = "tp") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    call = call or CallConfig(compute_dtype=jnp.bfloat16,
                              attention_impl="chunked", remat=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    n_params = param_count(cfg)
    n_active = param_count(cfg, active_only=True)

    params_shape = jax.eval_shape(partial(init_params, cfg, dtype=jnp.bfloat16),
                                  jax.random.PRNGKey(0))
    p_sh = param_shardings(cfg, mesh, params_shape, policy=policy)
    batch = input_specs(cfg, shape)
    b_sh = batch_shardings(cfg, shape, mesh, batch)

    t0 = time.time()
    if shape.kind == "train":
        opt = AdamWConfig(
            quantized_state=(n_params >= QUANTIZED_STATE_THRESHOLD))
        opt_shape = jax.eval_shape(partial(init_opt_state, opt), params_shape)
        o_sh = _opt_shardings(mesh, opt_shape, p_sh)
        step = make_train_step(cfg, call, opt)
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
        with mesh:
            lowered = jitted.lower(params_shape, opt_shape, batch)
            compiled = lowered.compile()
        tokens = shape.global_batch * shape.seq_len
        # train step ~ 3x forward FLOPs; 6ND counts fwd+bwd already
        n_for_flops = n_active
    else:
        # prefill is lowered as a train-shaped forward; decode uses the cache
        if shape.kind == "prefill":
            from repro.distributed.steps import make_prefill_step
            step = make_prefill_step(cfg, call)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=None)
            with mesh:
                lowered = jitted.lower(params_shape, batch)
                compiled = lowered.compile()
            tokens = shape.global_batch * shape.seq_len // 3  # fwd only: 2ND
        else:
            cshape = cache_specs(cfg, shape)
            c_sh = cache_shardings(cfg, shape, mesh, cshape)
            step = make_serve_step(cfg, call)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh, None),
                             out_shardings=(b_sh.get("tokens") or
                                            NamedSharding(mesh, P()), c_sh))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            with mesh:
                lowered = jitted.lower(params_shape, cshape, batch, pos)
                compiled = lowered.compile()
            tokens = shape.global_batch // 3  # one token, fwd only
        n_for_flops = n_active

    out, mem, cost = _analyze(compiled, n_dev, n_params, n_for_flops,
                              max(tokens, 1))
    out.update(arch=arch, shape=shape_name,
               mesh="multi" if multi_pod else "single", policy=policy,
               compile_s=round(time.time() - t0, 1),
               params_total=n_params, params_active=n_active)
    if verbose:
        print(f"== {arch} x {shape_name} x "
              f"{'2x16x16' if multi_pod else '16x16'} ==")
        print(mem)
        print({k: v for k, v in (cost or {}).items()
               if k in ("flops", "bytes accessed")})
        print(f"  compute={out['compute_s']*1e3:.2f}ms "
              f"memory={out['memory_s']*1e3:.2f}ms "
              f"collective={out['collective_s']*1e3:.2f}ms "
              f"dominant={out['dominant']} "
              f"useful_flops={out['useful_flop_ratio']:.2f} "
              f"[compile {out['compile_s']}s]")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attention", default="chunked",
                    choices=["dense", "chunked"])
    ap.add_argument("--policy", default="tp",
                    choices=["tp", "seqpar", "tp_gqa", "ep_data", "ep_seq"])
    ap.add_argument("--moe-group", type=int, default=1024)
    ap.add_argument("--seq-axis", default=None)
    ap.add_argument("--gqa-expand", action="store_true")
    ap.add_argument("--moe-ep-axis", default=None)
    ap.add_argument("--attn-chunk", type=int, default=512)
    ap.add_argument("--tag", default="",
                    help="artifact suffix (hillclimb variants)")
    args = ap.parse_args()
    ART.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    call = CallConfig(compute_dtype=jnp.bfloat16,
                      attention_impl=args.attention, remat=True,
                      attn_chunk=args.attn_chunk,
                      batch_axes=("pod", "data") if args.mesh == "multi"
                      else ("data",),
                      seq_axis=args.seq_axis,
                      gqa_expand_kv=args.gqa_expand,
                      moe_ep_axis=args.moe_ep_axis,
                      moe_group_size=args.moe_group)

    cells = []
    if args.all:
        for cfg, shape, ok in iter_cells():
            cells.append((cfg.name, shape.name, ok))
    else:
        cfg = get_config(args.arch)
        ok = SHAPES[args.shape].name != "long_500k" or cfg.sub_quadratic
        cells = [(args.arch, args.shape, ok)]

    n_fail = 0
    for arch, shape_name, ok in cells:
        for mp in meshes:
            tag = f"{arch}__{shape_name}__{'multi' if mp else 'single'}"
            if args.tag:
                tag += f"__{args.tag}"
            path = ART / f"{tag}.json"
            if not ok:
                rec = {"arch": arch, "shape": shape_name,
                       "mesh": "multi" if mp else "single",
                       "skipped": "full-attention arch; long_500k requires "
                                  "sub-quadratic support (DESIGN.md §5)"}
                path.write_text(json.dumps(rec, indent=1))
                print(f"-- skip {tag}")
                continue
            try:
                rec = run_cell(arch, shape_name, mp, call=call,
                               policy=args.policy)
                path.write_text(json.dumps(rec, indent=1, default=str))
            except Exception as e:  # noqa: BLE001 — report and continue
                n_fail += 1
                print(f"!! FAIL {tag}: {e}")
                traceback.print_exc()
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run cells failed")


if __name__ == "__main__":
    main()
