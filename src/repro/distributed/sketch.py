"""Fixed-size mergeable weighted quantile sketch, pure jnp.

The mesh-sharded sweep engine (core/experiment.py) reduces each grid
point's latency distribution ON DEVICE so a 10^4-point grid returns
O(bins) bytes per point instead of raw batch-record timelines. The sketch
is a rank-space histogram in the style of a weighted t-digest with
uniform (non-adaptive) centroid budget:

  - ``build`` sorts the (value, weight) pairs, assigns each entry to one
    of ``bins`` equal-probability rank buckets by its CDF *midpoint*
    ``(cum_w - w/2) / total_w``, and emits per-bucket weighted-mean
    centers + total weights. Centers are nondecreasing across occupied
    buckets (buckets partition the sorted order), empty buckets carry
    ``+inf`` centers at zero weight so they sort last and stay inert.
  - ``quantile`` runs the exact algorithm of
    ``repro.core.harness._weighted_quantile`` over the centroids
    (zero-weight entries only flatten the CDF; an all-zero sketch returns
    NaN), so a sketch whose buckets each hold one distinct value decodes
    quantiles EXACTLY — in particular any input with <= ``bins``
    equally-weighted distinct values (tests/test_sharded.py pins this).
  - ``merge`` concatenates two sketches' centroids and re-buckets, so
    per-shard sketches reduce associatively to a sweep-level digest.

Everything is float32 (dtype hygiene: no f64 creep into compiled sweep
programs) and shape-static, so ``build`` vmaps across grid points and
rides inside the shard_map'd sweep program.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

# Default centroid budget: 64 rank buckets resolve quantile ranks to
# ~1/64 (+-0.8%), enough to separate a p99 from a p95 while keeping a
# point's distribution payload at 512 bytes.
SKETCH_BINS = 64

Sketch = Dict[str, jax.Array]  # {"v": [bins] f32 centers, "w": [bins] f32}


def _bucketize(v: jax.Array, w: jax.Array, bins: int) -> Sketch:
    """Sorted (v, w) -> rank-bucketed centroids. Zero-weight entries add
    nothing (their w*v product is masked, not multiplied: v may be inf)."""
    cum = jnp.cumsum(w)
    tot = cum[-1]
    mid = (cum - 0.5 * w) / jnp.where(tot > 0, tot, 1.0)
    b = jnp.clip((mid * bins).astype(jnp.int32), 0, bins - 1)
    wsum = jnp.zeros((bins,), jnp.float32).at[b].add(w)
    vsum = jnp.zeros((bins,), jnp.float32).at[b].add(
        jnp.where(w > 0, w * v, 0.0))
    center = jnp.where(wsum > 0, vsum / jnp.where(wsum > 0, wsum, 1.0),
                       jnp.inf)
    return {"v": center.astype(jnp.float32), "w": wsum}


def build(values: jax.Array, weights: jax.Array,
          bins: int = SKETCH_BINS) -> Sketch:
    """Sketch a flat weighted sample. Traceable/vmappable; zero-weight
    entries are inert (values may be inf/nan at weight 0, matching the
    masked batch records the harness feeds in)."""
    v = values.ravel().astype(jnp.float32)
    w = weights.ravel().astype(jnp.float32)
    order = jnp.argsort(v)
    return _bucketize(v[order], w[order], bins)


def merge(a: Sketch, b: Sketch, bins: int = SKETCH_BINS) -> Sketch:
    """Combine two sketches into one of the same size (re-bucketing the
    union of centroids) — the on-device cross-point/cross-shard reduce."""
    v = jnp.concatenate([a["v"], b["v"]])
    w = jnp.concatenate([a["w"], b["w"]])
    order = jnp.argsort(v)
    return _bucketize(v[order], w[order], bins)


def quantile(sk: Sketch, q: float) -> jax.Array:
    """Decode one quantile — the exact ``harness._weighted_quantile``
    algorithm over the centroids (empty +inf buckets are never selected:
    the CDF reaches 1.0 on the last occupied bucket)."""
    order = jnp.argsort(sk["v"])
    v, w = sk["v"][order], sk["w"][order]
    cum = jnp.cumsum(w)
    tot = cum[-1]
    cdf = cum / jnp.where(tot > 0, tot, 1.0)
    idx = jnp.clip(jnp.searchsorted(cdf, q, side="left"), 0, v.shape[0] - 1)
    return jnp.where(tot > 0, v[idx], jnp.nan)


def quantile_np(v, w, q: float) -> float:
    """Host-side decode for collected sketches (plain numpy inputs).
    Matches the device decode bit-for-bit: the comparison runs in float32
    (jnp casts the weak-typed q down; float64 q here would step one bucket
    past ranks that land exactly on a bucket boundary)."""
    import numpy as np
    v = np.asarray(v, np.float32)
    w = np.asarray(w, np.float32)
    order = np.argsort(v)
    v, w = v[order], w[order]
    cum = np.cumsum(w, dtype=np.float32)
    tot = cum[-1]
    if not tot > 0:
        return float("nan")
    cdf = (cum / tot).astype(np.float32)
    idx = min(int(np.searchsorted(cdf, np.float32(q), side="left")),
              len(v) - 1)
    return float(v[idx])
