# One function per paper table/figure. Prints ``name,us_per_call,derived``.
"""Benchmark driver:
  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig6,roofline,...]
      [--cache-dir DIR] [--no-compile-cache]

Figure suites dispatch through the batched experiment engine
(repro.core.experiment): each protocol's whole rate grid compiles once and
runs as a single vmapped program — and, since the canonical-program-
signature work, the fig 6/7/9 suites all reuse ONE compiled program per
protocol, so only the first suite pays a trace.

The persistent XLA compilation cache (repro.core.compile_cache) is enabled
by default at the repo-local ``.jax_cache`` directory
(``JAX_COMPILATION_CACHE_DIR`` or ``--cache-dir`` overrides), so a repeat
run — another process, CI with the cache restored — skips XLA compilation
entirely and pays only tracing.

Every run also writes ``BENCH_core.json`` at the repo root: per-suite
wall-clock at millisecond precision, the compile-vs-run split, the
compile-accounting fields (jit traces, distinct program signatures,
persistent-cache hits/misses, true backend-compile seconds), and the
resolved channel-ring horizon — so the perf trajectory is tracked across
PRs. Microbench suites (channel/kernels) get their compile/run split from
the jax.monitoring backend-compile counters instead of the sweep engine's
dispatch timers. The ``channel`` suite's packed-vs-legacy comparison lands
in ``benchmarks/artifacts/channel_bench.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks import figures  # noqa: E402
from benchmarks import roofline  # noqa: E402
from benchmarks.bench_kernels import bench as kernel_bench  # noqa: E402
from benchmarks.bench_kernels import bench_channel  # noqa: E402
from repro.core import compile_cache, experiment  # noqa: E402
from repro.obs import history  # noqa: E402
from repro.obs import monitor as obs_monitor  # noqa: E402

REPO = Path(__file__).resolve().parents[1]

# per-suite extra BENCH_core blocks filled in by suite functions (the
# channel suite's HLO/roofline analysis); merged into the suite entries
_EXTRA: dict = {}


def sanitize_entry(entry: dict) -> dict:
    """Report-layer hygiene for one BENCH_core suite entry. The cache
    counters clamp per event inside compile_cache, but entries written by
    OLDER revisions (merged back in by partial ``--only`` runs) can still
    carry a negative ``cache_saved_s`` — clamp here too so the tracked
    file never shows negative savings regardless of which revision wrote
    the stale entry."""
    e = dict(entry)
    if "cache_saved_s" in e:
        try:
            e["cache_saved_s"] = round(max(float(e["cache_saved_s"]), 0.0),
                                       3)
        except (TypeError, ValueError):
            pass
    return e


def merge_suites(prev: dict, current: dict) -> dict:
    """Fold this run's suite entries over a previous BENCH_core.json
    (partial ``--only`` runs update just the suites they ran), sanitizing
    BOTH sides at the merge layer."""
    merged: dict = {}
    if isinstance(prev, dict):
        for n, e in (prev.get("suites") or {}).items():
            if isinstance(e, dict):
                merged[n] = sanitize_entry(e)
    for n, e in current.items():
        merged[n] = sanitize_entry(e)
    return merged


def _scaling_suite(quick: bool) -> list:
    """Mesh-sharded sweep engine curve (figures.scaling_curve): ~10^3
    points through ``dispatch_sweep(mesh=...)`` per available device
    count. Multi-device on CPU requires
    XLA_FLAGS=--xla_force_host_platform_device_count=8 in the job env."""
    rows = figures.scaling_curve(sim_seconds=0.25 if quick else 0.5)
    _EXTRA["scaling"] = {
        "scaling": figures.SCALING.pop("scaling"),
        # opcode-level HBM attribution of the per-point program (where the
        # packed ring scatter sits now that run time is the bottleneck)
        "sweep_hlo": roofline.sweep_hlo_block(0.25 if quick else 0.5),
    }
    return rows


def _channel_suite() -> list:
    rows = bench_channel()
    art = {r[0]: {"us_per_tick": r[1], "derived": r[2]} for r in rows}
    (figures.ART / "channel_bench.json").write_text(
        json.dumps(art, indent=1))
    # HLO cost + roofline terms of the packed loop just timed above
    _EXTRA["channel"] = {"hlo_roofline": roofline.channel_hlo_block()}
    return rows


def _hlo_audit_suite(sim_s: float) -> list:
    """Tracelint as a benchmark suite: AST repo lint + HLO program audit
    (repro.analysis). The monitor-shaped verdict lands in the suite's
    ``monitor`` key so H1–H4 violations gate through history.compare like
    runtime invariant violations; per-rule active-finding counts land in
    the ``analysis`` block so lint debt is a trajectory."""
    from repro.analysis import hlo_lint, run_lint
    report = run_lint(REPO / "src" / "repro")
    verdict = hlo_lint.audit(sim_seconds=sim_s, report=report)
    figures.VERDICTS["hlo-audit"] = verdict
    counts = {"active": len(report.active)}
    counts.update(report.counts())
    _EXTRA["hlo-audit"] = {"analysis": counts}
    rows = []
    for proto, d in verdict["protocols"].items():
        if d.get("program", "x") is None:
            rows.append((f"hlo-audit/{proto}", 0.0, "analytic:clean"))
        else:
            rows.append((f"hlo-audit/{proto}", 0.0,
                         f"f64={d['f64_ops']};xfer_in_loop="
                         f"{d['host_transfers_in_loop']};"
                         f"scan_while={d['scan_whiles']}"))
    for tag, sigs in verdict["signatures"].items():
        rows.append((f"hlo-audit/grid-{tag}", 0.0,
                     f"signatures={len(sigs)}"))
    rows.append(("hlo-audit/ast", 0.0,
                 f"active={len(report.active)};"
                 f"findings={len(report.findings)}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter sims (2s instead of 4s)")
    ap.add_argument("--only", default="")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent compile-cache directory "
                         "(default: repo-local .jax_cache)")
    ap.add_argument("--no-compile-cache", action="store_true",
                    help="disable the persistent XLA compilation cache "
                         "(every process recompiles)")
    ap.add_argument("--no-history", action="store_true",
                    help="skip the BENCH_history.jsonl append + "
                         "regression comparison")
    args, _ = ap.parse_known_args()
    sim_s = 2.0 if args.quick else 4.0
    only = set(args.only.split(",")) if args.only else None

    if args.no_compile_cache:
        compile_cache.disable()
    else:
        cache_dir = compile_cache.enable(args.cache_dir)
        print(f"# persistent compile cache: {cache_dir}", file=sys.stderr)

    figures.ART.mkdir(parents=True, exist_ok=True)
    suites = {
        "fig6": lambda: figures.fig6_throughput_latency(sim_s),
        "fig7": lambda: figures.fig7_crash(sim_s),
        "fig8": lambda: figures.fig8_ddos(sim_s),
        "fig9": lambda: figures.fig9_scalability(max(sim_s - 1, 2.0)),
        "robustness": lambda: figures.robustness(sim_s),
        "workload-matrix": lambda: figures.workload_matrix(sim_s),
        "scaling": lambda: _scaling_suite(args.quick),
        "paper": figures.paper_comparison,
        "kernels": kernel_bench,
        "channel": _channel_suite,
        "roofline_single": lambda: roofline.rows("single"),
        "roofline_multi": lambda: roofline.rows("multi"),
        "hlo-audit": lambda: _hlo_audit_suite(sim_s),
    }
    if only:
        unknown = only - suites.keys()
        if unknown:
            sys.exit(f"unknown suite(s): {', '.join(sorted(unknown))}; "
                     f"valid: {', '.join(suites)}")
    print("name,us_per_call,derived")
    errored = []
    bench_core: dict = {"suites": {}}
    # traces/signatures accumulate ACROSS suites (per-suite deltas below):
    # resetting between suites would hide that fig7/fig9 reuse fig6's
    # canonical program — a 0-trace suite is the headline, not an artifact
    experiment.reset_trace_counts()
    for name, fn in suites.items():
        if only and name not in only:
            continue
        experiment.reset_timing_stats()
        cache0 = compile_cache.stats()
        traces0 = sum(experiment.trace_counts().values())
        t0 = time.perf_counter()
        suite_error = None
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            errored.append(name)
            suite_error = type(e).__name__
        wall = time.perf_counter() - t0
        stats = experiment.timing_stats()
        cache_d = compile_cache.delta(cache0)
        # 3-decimal (ms) precision everywhere: warm-cache suites run in
        # milliseconds, and "0.0" is not a trajectory point
        entry = {
            # per-suite so merged files can't mix quick/full timings
            # under one misleading top-level flag
            "quick": args.quick,
            "wall_s": round(wall, 3),
            # first-dispatch (trace+compile+first run) vs cache-hit split
            # from the sweep engine; microbench suites have no sweep
            # dispatches, so their split comes from the monitoring-based
            # backend-compile counters instead
            "compile_s": round(sum(s["compile_s"] for s in stats.values()),
                               3),
            "run_s": round(sum(s["run_s"] for s in stats.values()), 3),
            # compile accounting (repro.core.compile_cache + experiment):
            # jit traces this suite, true XLA backend-compile seconds, and
            # persistent-cache traffic — a warm suite shows traces>0 but
            # misses==0 and xla_compile_s~0
            "traces": sum(experiment.trace_counts().values()) - traces0,
            "xla_compile_s": round(cache_d["backend_compile_s"], 3),
            "cache_hits": cache_d["persistent_cache_hits"],
            "cache_misses": cache_d["persistent_cache_misses"],
            "cache_saved_s": round(max(cache_d["compile_saved_s"], 0.0), 3),
        }
        if not stats:
            entry["compile_s"] = entry["xla_compile_s"]
            entry["run_s"] = round(wall - cache_d["backend_compile_s"], 3)
        if suite_error is not None:
            # a partial run's wall-clock is not a trajectory point —
            # mark it so cross-PR comparisons can filter it out
            entry["error"] = suite_error
        horizons = {p: s["horizon"] for p, s in stats.items()
                    if s.get("horizon")}
        if horizons:
            entry["ring_horizon"] = horizons
        entry.update(_EXTRA.pop(name, {}))
        # flight-recorder telemetry (phase breakdowns; only present when
        # REPRO_TRACE != off, so default BENCH_core entries are unchanged)
        tele = figures.TELEMETRY.pop(name, None)
        if tele:
            entry["telemetry"] = tele
        # health-monitor verdict (only present when REPRO_MONITOR != off):
        # aggregated over every sweep point the suite collected
        mverdict = figures.VERDICTS.pop(name, None)
        if mverdict is not None:
            entry["monitor"] = mverdict
        bench_core["suites"][name] = entry
        msg = (f"# {name} done in {wall:.2f}s "
               f"({entry['traces']} new traces, "
               f"{entry['cache_misses']} compile-cache misses")
        if mverdict is not None:
            msg += f", {obs_monitor.format_verdict(mverdict)}"
        print(msg + ")", file=sys.stderr)
    # distinct canonical programs per protocol, across every suite run
    bench_core["programs"] = {
        p: len(s) for p, s in experiment.program_signatures().items()}
    # history entry covers THIS run's suites only — snapshot before the
    # merge below folds in stale suites from a previous BENCH_core.json
    run_suites = {n: dict(e) for n, e in bench_core["suites"].items()}
    # merge into the tracked trajectory file: partial (--only) runs update
    # just the suites they ran instead of discarding the rest
    bench_path = REPO / "BENCH_core.json"
    if bench_path.exists():
        try:
            prev = json.loads(bench_path.read_text())
            bench_core["suites"] = merge_suites(prev, bench_core["suites"])
        except (json.JSONDecodeError, AttributeError):
            pass
    bench_path.write_text(json.dumps(bench_core, indent=1) + "\n")
    if run_suites and not args.no_history:
        # append-and-compare ledger: every run lands one schema-validated
        # line in BENCH_history.jsonl; the comparison against the previous
        # entry is what the CI health job gates on
        hist_path = REPO / "BENCH_history.jsonl"
        base = history.latest(hist_path)
        entry = history.make_entry(run_suites, quick=args.quick,
                                   git_sha=history.git_sha(REPO),
                                   timestamp=time.time())
        history.append(hist_path, entry)
        cmp_res = history.compare(base, entry)
        for line in history.format_compare(cmp_res):
            print(f"# history: {line}", file=sys.stderr)
    roofline.main()
    if errored:
        sys.exit(f"suite(s) errored: {', '.join(errored)}")


if __name__ == "__main__":
    main()
