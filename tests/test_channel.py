"""Channel invariants (core/channel.py): delayed delivery lands exactly at
t + clip(delay, 1, dmax-1) (horizon-edge clipping included), colliding
slots merge by elementwise max (monotone payloads) or add (counters),
fold_state is monotone, and the drop mask is a silent omission. Property
tests drive random delay matrices / payloads (hypothesis; degrades to
fixed-seed cases when it is not installed, matching the repo pattern)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core import channel as ch

DMAX, N, P = 16, 4, 3


def _as_np(x):
    return np.asarray(x)


def _roundtrip_case(seed: int):
    """Random delays (some past the horizon), random send mask: every
    masked message is delivered exactly once, at t + clip(delay, 1, dmax-1),
    with its exact payload; fold_state only ever grows."""
    rng = np.random.RandomState(seed)
    delays = rng.randint(0, 2 * DMAX, size=(N, N))
    payload = rng.uniform(0.0, 100.0, (N, N, P)).astype(np.float32)
    mask = rng.rand(N, N) < 0.7
    c = ch.make_channel(DMAX, N, P)
    c = ch.send(c, jnp.int32(0), jnp.asarray(payload),
                jnp.asarray(delays, jnp.int32), jnp.asarray(mask))
    eff = np.clip(delays, 1, DMAX - 1)
    state = jnp.full((N, N, P), ch.NEG, jnp.float32)
    seen = np.zeros((N, N), bool)
    for t in range(1, DMAX):
        c, flags, pay = ch.deliver(c, jnp.int32(t))
        f = _as_np(flags)
        expect = mask & (eff == t)
        assert np.array_equal(f, expect), f"delivery flags wrong at t={t}"
        assert np.array_equal(_as_np(pay)[f], payload[f]), \
            "payload not delivered verbatim"
        prev = _as_np(state)
        state = ch.fold_state(state, flags, pay)
        assert (_as_np(state) >= prev).all(), "fold_state not monotone"
        seen |= f
    assert np.array_equal(seen, mask), "some masked message never delivered"
    # every slot was popped once: the channel is empty again
    assert not _as_np(c["flag"]).any()
    assert (_as_np(c["buf"]) == ch.NEG).all()


def _collision_case(seed: int):
    """Two same-tick sends landing in one slot merge elementwise-max —
    the delivered message is one the protocol could have received later."""
    rng = np.random.RandomState(seed)
    pa = rng.uniform(0.0, 50.0, (N, N, P)).astype(np.float32)
    pb = rng.uniform(0.0, 50.0, (N, N, P)).astype(np.float32)
    ones = jnp.ones((N, N), jnp.bool_)
    delay = jnp.full((N, N), 5, jnp.int32)
    c = ch.make_channel(DMAX, N, P)
    c = ch.send(c, jnp.int32(0), jnp.asarray(pa), delay, ones)
    c = ch.send(c, jnp.int32(0), jnp.asarray(pb), delay, ones)
    for t in range(1, 6):
        c, flags, pay = ch.deliver(c, jnp.int32(t))
        if t < 5:
            assert not _as_np(flags).any()
    assert _as_np(flags).all()
    assert np.array_equal(_as_np(pay), np.maximum(pa, pb))


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 2 ** 16 - 1))
    def test_send_deliver_roundtrip(seed):
        _roundtrip_case(seed)

    @settings(max_examples=3, deadline=None)
    @given(st.integers(0, 2 ** 16 - 1))
    def test_colliding_slots_merge_max(seed):
        _collision_case(seed)
else:
    def test_send_deliver_roundtrip():
        """Degraded fixed-case variant (hypothesis not installed)."""
        for seed in (0, 1, 12345):
            _roundtrip_case(seed)

    def test_colliding_slots_merge_max():
        """Degraded fixed-case variant (hypothesis not installed)."""
        _collision_case(7)


def test_horizon_edge_clips_to_dmax_minus_1():
    """delay >= dmax is delivered at the horizon (dmax-1), never wraps into
    an earlier slot; delay 0 is bumped to 1 (no same-tick delivery)."""
    ones = jnp.ones((N, N), jnp.bool_)
    pay = jnp.ones((N, N, P), jnp.float32)
    for d in (0, DMAX - 1, DMAX, 3 * DMAX + 2):
        c = ch.make_channel(DMAX, N, P)
        c = ch.send(c, jnp.int32(0), pay, jnp.full((N, N), d, jnp.int32),
                    ones)
        expect_t = int(np.clip(d, 1, DMAX - 1))
        for t in range(1, DMAX):
            c, flags, _ = ch.deliver(c, jnp.int32(t))
            assert _as_np(flags).any() == (t == expect_t), \
                f"delay {d}: delivery at t={t}"


def test_additive_channel_accumulates():
    c = ch.make_channel(DMAX, N, 2, additive=True)
    ones = jnp.ones((N, N), jnp.bool_)
    pay = jnp.full((N, N, 2), 3.0, jnp.float32)
    delay = jnp.full((N, N), 4, jnp.int32)
    c = ch.send(c, jnp.int32(0), pay, delay, ones, additive=True)
    c = ch.send(c, jnp.int32(0), pay, delay, ones, additive=True)
    for t in range(1, 5):
        c, flags, got = ch.deliver(c, jnp.int32(t))
    assert _as_np(flags).all()
    assert (np.asarray(got) == 6.0).all()


def test_drop_mask_is_silent_omission():
    """A dropped link delivers nothing; untouched links are unaffected —
    byte-for-byte the same as an undropped send elsewhere."""
    rng = np.random.RandomState(3)
    pay = rng.uniform(0.0, 10.0, (N, N, P)).astype(np.float32)
    ones = jnp.ones((N, N), jnp.bool_)
    drop = np.zeros((N, N), bool)
    drop[0, 1] = drop[2, 3] = True
    delay = jnp.full((N, N), 2, jnp.int32)
    c = ch.make_channel(DMAX, N, P)
    c = ch.send(c, jnp.int32(0), jnp.asarray(pay), delay, ones,
                drop=jnp.asarray(drop))
    c, f1, _ = ch.deliver(c, jnp.int32(1))
    c, f2, got = ch.deliver(c, jnp.int32(2))
    assert not _as_np(f1).any()
    assert np.array_equal(_as_np(f2), ~drop)
    assert np.array_equal(_as_np(got)[~drop], pay[~drop])
