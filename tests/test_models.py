"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape + finiteness asserts; train-vs-decode consistency; param accounting."""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, param_count
from repro.models import (CallConfig, forward_decode, forward_train,
                          init_cache, init_params, loss_fn)

CALL = CallConfig(compute_dtype=jnp.float32, attention_impl="dense",
                  remat=False)
KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16, with_labels=False):
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    else:
        batch["frame_emb"] = 0.1 * jax.random.normal(KEY, (b, s, cfg.d_model))
    if cfg.cross_attn is not None:
        batch["vision_mem"] = 0.1 * jax.random.normal(
            KEY, (b, cfg.cross_attn.n_mem_tokens, cfg.d_model))
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    batch = _batch(cfg, with_labels=True)
    logits, aux = forward_train(params, cfg, CALL, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    (loss, parts), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, CALL, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(jnp.sum(jnp.abs(g)) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_decode_consistency(arch):
    """Token-by-token decode reproduces the parallel train-mode logits
    (MoE capacity forced high so routing is batch-independent)."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = init_params(cfg, KEY)
    b, s = 2, 8
    batch = _batch(cfg, b, s)
    logits, _ = forward_train(params, cfg, CALL, batch)
    cache = init_cache(cfg, b, s, jnp.float32)
    errs = []
    for t in range(s):
        db = dict(batch)
        if cfg.embed_inputs:
            db["tokens"] = batch["tokens"][:, t]
        else:
            db["frame_emb"] = batch["frame_emb"][:, t:t + 1]
        lg, cache = forward_decode(params, cfg, CALL, db, cache, jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg - logits[:, t]))))
    assert max(errs) < 5e-3, errs


@pytest.mark.parametrize("arch", list_archs())
def test_param_count_exact(arch):
    cfg = get_config(arch).reduced()
    shape = jax.eval_shape(partial(init_params, cfg), KEY)
    actual = sum(l.size for l in jax.tree.leaves(shape))
    assert param_count(cfg) == actual


def test_attention_impls_agree():
    cfg = get_config("qwen3-14b").reduced()
    params = init_params(cfg, KEY)
    batch = _batch(cfg, 2, 32)
    outs = []
    for impl in ("dense", "chunked"):
        call = dataclasses.replace(CALL, attention_impl=impl, attn_chunk=16)
        logits, _ = forward_train(params, cfg, call, batch)
        outs.append(logits)
    assert float(jnp.max(jnp.abs(outs[0] - outs[1]))) < 1e-3


def test_moe_group_invariance_with_high_capacity():
    from repro.models.moe import init_moe, moe_mlp
    cfg = get_config("dbrx-132b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    pm = init_moe(cfg, KEY)
    x = 0.5 * jax.random.normal(KEY, (2, 16, cfg.d_model))
    y_all, _ = moe_mlp(pm, x, cfg=cfg)
    y_tok = jnp.concatenate(
        [moe_mlp(pm, x[:, t:t + 1], cfg=cfg)[0] for t in range(16)], axis=1)
    assert float(jnp.max(jnp.abs(y_all - y_tok))) < 1e-5


def test_long_context_shapes_skip_rule():
    from repro.configs import SHAPES, shape_supported
    long = SHAPES["long_500k"]
    expect = {"xlstm-1.3b": True, "jamba-1.5-large-398b": True,
              "qwen3-32b": False, "smollm-135m": False}
    for arch, ok in expect.items():
        assert shape_supported(get_config(arch), long) == ok
