"""Sporades (Algorithms 2 + 3) — dual-mode omission-fault-tolerant consensus,
composed with Mandator: block payloads are Mandator vector clocks.

Faithful protocol, simulator-native encoding:
- rank (v, r) is packed into an int key  v*RS + r  (lexicographic order
  preserved; RS bounds rounds-per-view); float32 channel payloads stay
  exact below 2^24.
- every message type is a monotone payload (see channel.py); receivers keep
  *latest-state* matrices and triggers fire on state predicates, not message
  events — so a replica that exits the async path still reacts to votes that
  arrived while it was async (omission-tolerant by construction).
- the common coin is the shared-seed PRNG of core/coin.py (§3.2.1).

Synchronous path: lines 9-28 of Alg. 2. Asynchronous path: lines 1-36 of
Alg. 3, including Bfall catch-up and the "first n-f asynchronous-complete"
commit rule (tracked via arrival ticks).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.smr import SMRConfig
from repro.core import channel as ch
from repro.core import netsim
from repro.core.coin import coin_table
from repro.obs import monitor as hmon
from repro.obs import trace as obs

RS = 1 << 14                    # rounds-per-view bound (rank key packing)
MAX_VIEWS = 4096


def key(v, r):
    return v * RS + r


def ring_spec(n: int) -> ch.RingSpec:
    """Packed delivery ring: all six Sporades message types in one fused
    [Dmax, n, n, K] buffer (the seed carried six separate rings)."""
    return ch.RingSpec(
        ch.ChannelSpec("prop", 2 + 2 * n),
        ch.ChannelSpec("vote", 2 + n),
        ch.ChannelSpec("to", 2 + n),
        ch.ChannelSpec("pa", 1 + n),
        ch.ChannelSpec("va", n),
        ch.ChannelSpec("ac", 2 + n),
    )


def init_state(cfg: SMRConfig, n_ticks: int) -> Dict:
    n = cfg.n_replicas
    dmax = cfg.delay_horizon_ticks
    z = lambda *s: jnp.zeros(s, jnp.int32)
    # flight recorder: absent at trace_level="off" (see mandator.init_state)
    tr = obs.init_trace(obs.DEFAULT_SPEC, cfg.trace_level, n,
                        cfg.trace_events)
    extra = {"tr": tr} if tr is not None else {}
    # health monitor per-tick IO gauges: absent at monitor_level="off"
    if hmon.on(cfg.monitor_level):
        extra["mon_io"] = {"dropped": jnp.zeros((n,), jnp.int32)}
    return {
        **extra,
        "v_cur": z(n), "r_cur": z(n),
        "is_async": jnp.zeros((n,), jnp.bool_),
        "bh_key": z(n), "bh_vc": z(n, n),
        "commit_key": z(n), "cvc": z(n, n),
        "prop_key": z(n), "last_vote_trig": jnp.full((n,), -1, jnp.int32),
        # first deadline = one view timeout from t=0
        "deadline": jnp.full((n,), cfg.view_timeout_ms / cfg.tick_ms,
                             jnp.float32),
        "timeout_sent_v": jnp.full((n,), -1, jnp.int32),
        "async_phase": z(n), "my_r": z(n), "my_avc": z(n, n),
        "exited_view": jnp.full((n,), -1, jnp.int32),
        "ac_tick": jnp.full((n, n), jnp.inf, jnp.float32),
        "ac_v_seen": jnp.full((n, n), -1, jnp.int32),
        # latest-state matrices [receiver, sender, fields]
        "vote_st": jnp.zeros((n, n, 2 + n), jnp.float32),
        "to_st": jnp.full((n, n, 2 + n), -1.0, jnp.float32),
        "pa_st": jnp.full((n, n, 1 + n), -1.0, jnp.float32),
        # vote-async is broadcast; field p of a voter's payload is the key of
        # the latest block from proposer p it voted for (enables the
        # Theorem-9 catch-up: adopt any h1 that gathered n-f votes)
        "va_st": jnp.full((n, n, n), -1.0, jnp.float32),
        "ac_st": jnp.full((n, n, 2 + n), -1.0, jnp.float32),
        # all six message types share ONE packed delivery ring
        "ring": ch.make_ring(ring_spec(n), dmax, n),
        "coins": coin_table(MAX_VIEWS, n),
    }


def _leader_of(v, n):
    return v % n


def tick(st: Dict, t: jax.Array, env: Dict, cfg: SMRConfig,
         lcr: jax.Array) -> Dict:
    """One simulator tick. lcr: Mandator getClientRequests() per replica
    [n, n] (row i = replica i's vector clock)."""
    n = cfg.n_replicas
    f = (n - 1) // 2
    q = n - f
    alive = netsim.alive(env, t)
    delays = netsim.link_delay(env, t).astype(jnp.int32)
    drop = netsim.link_drop(env, t)
    to_ticks = jnp.float32(cfg.view_timeout_ms / cfg.tick_ms)
    st = dict(st)
    tf = t.astype(jnp.float32)
    rows = jnp.arange(n)
    lcr_f = lcr.astype(jnp.float32)
    # one fused pop of slot t for every channel; sends buffer up and commit
    # as one fused scatter at the end of the tick (same-tick sends always
    # land at t+1 or later, so the reorder is exact — channel.py)
    spec = ring_spec(n)
    msgs = ch.ring_deliver(spec, st["ring"], t)
    sends = []

    v_cur, r_cur = st["v_cur"], st["r_cur"]
    is_async = st["is_async"]
    bh_key, bh_vc = st["bh_key"], st["bh_vc"].astype(jnp.float32)
    commit_key, cvc = st["commit_key"], st["cvc"].astype(jnp.float32)
    deadline = st["deadline"]

    # ---- 1) deliver <propose> (Alg2 lines 20-26) --------------------------
    pfl, ppay = msgs["prop"]
    arr = jnp.swapaxes(ppay, 0, 1)                       # [rcv, snd, P]
    afl = jnp.swapaxes(pfl, 0, 1)
    ps = jnp.max(jnp.where(afl[..., None], arr, -1.0), axis=1)   # [rcv, P]
    got_prop = afl.any(axis=1)
    pb_key = ps[:, 0].astype(jnp.int32)
    pc_key = ps[:, 1].astype(jnp.int32)
    p_vc = ps[:, 2:2 + n]
    p_cvc = ps[:, 2 + n:]
    accept = got_prop & alive & ~is_async & (pb_key > key(v_cur, r_cur))
    cvc = jnp.where(accept[:, None], jnp.maximum(cvc, p_cvc), cvc)
    commit_key = jnp.where(accept, jnp.maximum(commit_key, pc_key), commit_key)
    v_cur = jnp.where(accept, pb_key // RS, v_cur)
    r_cur = jnp.where(accept, pb_key % RS, r_cur)
    bh_key = jnp.where(accept, pb_key, bh_key)
    bh_vc = jnp.where(accept[:, None], p_vc, bh_vc)
    deadline = jnp.where(accept, tf + to_ticks, deadline)
    # send <vote> to L_v (line 25)
    vote_pay = jnp.concatenate(
        [bh_key[:, None].astype(jnp.float32), bh_key[:, None].astype(jnp.float32),
         bh_vc], axis=1)[:, None, :] * jnp.ones((n, n, 1))
    vote_mask = accept[:, None] & (jnp.arange(n)[None, :]
                                   == _leader_of(v_cur, n)[:, None])
    sends.append(ch.Send("vote", vote_pay, delays, vote_mask))

    # ---- 2) deliver <vote>; leader trigger (Alg2 lines 9-19) --------------
    vfl, vpay = msgs["vote"]
    vote_st = ch.fold_state(st["vote_st"], vfl, vpay)
    voted = vote_st[:, :, 0].astype(jnp.int32)           # [ldr, voter]
    kmax = jnp.max(voted, axis=1)
    match = voted == kmax[:, None]
    cnt = jnp.sum(match, axis=1)
    lead_trig = (alive & ~is_async & (cnt >= q)
                 & (kmax >= key(v_cur, r_cur)) & (kmax > st["last_vote_trig"])
                 & (_leader_of(kmax // RS, n) == rows))
    vbh = vote_st[:, :, 1].astype(jnp.int32)
    bh_new = jnp.max(jnp.where(match, vbh, -1), axis=1)
    vvc = vote_st[:, :, 2:]
    bh_vc_new = jnp.max(jnp.where(match[..., None], vvc, -1.0), axis=1)
    # commit check (line 11): n-f votes whose block_high rank == voted rank
    cnt_bh = jnp.sum(match & (vbh == kmax[:, None]), axis=1)
    lead_commit = lead_trig & (cnt_bh >= q)
    commit_key = jnp.where(lead_commit, jnp.maximum(commit_key, kmax), commit_key)
    cvc = jnp.where(lead_commit[:, None], jnp.maximum(cvc, bh_vc_new), cvc)
    v_cur = jnp.where(lead_trig, kmax // RS, v_cur)
    r_cur = jnp.where(lead_trig, kmax % RS, r_cur)
    bh_key = jnp.where(lead_trig, jnp.maximum(bh_key, bh_new), bh_key)
    bh_vc = jnp.where(lead_trig[:, None], jnp.maximum(bh_vc, bh_vc_new), bh_vc)
    # form + broadcast new block (lines 15-18)
    new_key = key(v_cur, r_cur + 1)
    prop_vc = jnp.maximum(lcr_f, bh_vc)
    prop_pay = jnp.concatenate(
        [new_key[:, None].astype(jnp.float32),
         commit_key[:, None].astype(jnp.float32), prop_vc, cvc],
        axis=1)[:, None, :] * jnp.ones((n, n, 1))
    sends.append(ch.Send("prop", prop_pay, delays,
                         lead_trig[:, None] & jnp.ones((n, n), jnp.bool_)))
    prop_key = jnp.where(lead_trig, new_key, st["prop_key"])
    # (leader's own block_high advances via self-delivery of its propose)
    last_vote_trig = jnp.where(lead_trig, kmax, st["last_vote_trig"])

    # ---- 3) timeout (Alg2 lines 27-28) ------------------------------------
    fire = alive & ~is_async & (tf >= deadline) & (st["timeout_sent_v"] < v_cur)
    to_pay = jnp.concatenate(
        [v_cur[:, None].astype(jnp.float32), bh_key[:, None].astype(jnp.float32),
         bh_vc], axis=1)[:, None, :] * jnp.ones((n, n, 1))
    sends.append(ch.Send("to", to_pay, delays,
                         fire[:, None] & jnp.ones((n, n), jnp.bool_)))
    timeout_sent_v = jnp.where(fire, v_cur, st["timeout_sent_v"])

    # ---- 4) deliver <timeout>; async entry (Alg3 lines 1-7) ---------------
    tfl, tpay = msgs["to"]
    to_st = ch.fold_state(st["to_st"], tfl, tpay)
    to_v = to_st[:, :, 0].astype(jnp.int32)
    tvmax = jnp.max(to_v, axis=1)
    tmatch = to_v == tvmax[:, None]
    tcnt = jnp.sum(tmatch, axis=1)
    enter = alive & ~is_async & (tcnt >= q) & (tvmax >= v_cur)
    tbh = jnp.max(jnp.where(tmatch, to_st[:, :, 1].astype(jnp.int32), -1), axis=1)
    tbh_vc = jnp.max(jnp.where(tmatch[..., None], to_st[:, :, 2:], -1.0), axis=1)
    bh_key = jnp.where(enter, jnp.maximum(bh_key, tbh), bh_key)
    bh_vc = jnp.where(enter[:, None], jnp.maximum(bh_vc, tbh_vc), bh_vc)
    v_cur = jnp.where(enter, tvmax, v_cur)
    r_cur = jnp.where(enter, jnp.maximum(r_cur, bh_key % RS), r_cur)
    is_async = is_async | enter
    # height-1 async block (lines 5-7)
    r1 = r_cur + 1
    avc = jnp.maximum(lcr_f, bh_vc)
    pa_key1 = (v_cur * 2 + 1) * RS + r1
    pa_pay = jnp.concatenate(
        [pa_key1[:, None].astype(jnp.float32), avc], axis=1)[:, None, :] \
        * jnp.ones((n, n, 1))
    sends.append(ch.Send("pa", pa_pay, delays,
                         enter[:, None] & jnp.ones((n, n), jnp.bool_)))
    async_phase = jnp.where(enter, 1, st["async_phase"])
    my_r = jnp.where(enter, r1, st["my_r"])
    my_avc = jnp.where(enter[:, None], avc, st["my_avc"].astype(jnp.float32))
    deadline = jnp.where(enter, jnp.inf, deadline)

    # ---- 5) deliver <propose-async>; vote (Alg3 lines 8-14) ---------------
    pafl, papay = msgs["pa"]
    pa_st = ch.fold_state(st["pa_st"], pafl, papay)
    pa_arr = jnp.swapaxes(pafl, 0, 1)                    # [rcv, snd]
    pa_k = pa_st[:, :, 0].astype(jnp.int32)
    pa_vh = pa_k // RS
    pa_h = jnp.where(pa_vh % 2 == 1, 1, 2)
    pa_v = (pa_vh - pa_h) // 2
    pa_r = pa_k % RS
    va_vote = (pa_arr & alive[:, None] & is_async[:, None]
               & (pa_v == v_cur[:, None]) & (pa_r > r_cur[:, None]))
    # broadcast vote: field p = key of p's block being voted (else -1)
    va_fields = jnp.where(va_vote, pa_k.astype(jnp.float32), -1.0)  # [i, p]
    va_pay = jnp.broadcast_to(va_fields[:, None, :], (n, n, n))
    sends.append(ch.Send(
        "va", va_pay, delays,
        va_vote.any(axis=1)[:, None] & jnp.ones((n, n), jnp.bool_)))

    # ---- 6) deliver <vote-async>; heights (Alg3 lines 15-23) --------------
    vafl, vapay = msgs["va"]
    va_st = ch.fold_state(st["va_st"], vafl, vapay)
    va_own = va_st[rows, :, rows].astype(jnp.int32)      # [rcv, voter]
    my_h1_key = (v_cur * 2 + 1) * RS + my_r
    my_h2_key = (v_cur * 2 + 2) * RS + my_r
    cnt_h1 = jnp.sum(va_own == my_h1_key[:, None], axis=1)
    cnt_h2 = jnp.sum(va_own == my_h2_key[:, None], axis=1)
    to_h2 = alive & is_async & (async_phase == 1) & (cnt_h1 >= q)
    # Theorem-9 catch-up: adopt any height-1 block of this view that
    # gathered n-f votes, if our own h1 is not getting votes
    va_all = va_st.astype(jnp.int32)                     # [rcv, voter, p]
    k_p = jnp.max(va_all, axis=1)                        # [rcv, p]
    cnt_p = jnp.sum(va_all == k_p[:, None, :], axis=1)   # [rcv, p]
    kp_vh = k_p // RS
    kp_is_h1 = (kp_vh % 2 == 1) & ((kp_vh - 1) // 2 == v_cur[:, None])
    adoptable = (cnt_p >= q) & kp_is_h1 & (k_p % RS >= my_r[:, None])
    adopt_key = jnp.max(jnp.where(adoptable, k_p, -1), axis=1)
    adopt_p = jnp.argmax(jnp.where(adoptable, k_p, -1), axis=1)
    adopt = alive & is_async & (async_phase == 1) & ~to_h2 & (adopt_key >= 0)
    # vc for the adopted parent, if we have its propose-async
    pa_p_key = jnp.take_along_axis(pa_k, adopt_p[:, None], axis=1)[:, 0]
    pa_p_vc = jnp.take_along_axis(pa_st[:, :, 1:], adopt_p[:, None, None],
                                  axis=1)[:, 0]
    adopt_vc = jnp.where((pa_p_key == adopt_key)[:, None], pa_p_vc, my_avc)
    go_h2 = to_h2 | adopt
    r2 = jnp.where(adopt, adopt_key % RS + 1, my_r + 1)
    avc2 = jnp.maximum(lcr_f, jnp.where(adopt[:, None], adopt_vc, my_avc))
    pa_key2 = (v_cur * 2 + 2) * RS + r2
    pa_pay2 = jnp.concatenate(
        [pa_key2[:, None].astype(jnp.float32), avc2], axis=1)[:, None, :] \
        * jnp.ones((n, n, 1))
    sends.append(ch.Send("pa", pa_pay2, delays,
                         go_h2[:, None] & jnp.ones((n, n), jnp.bool_)))
    my_r = jnp.where(go_h2, r2, my_r)
    my_avc = jnp.where(go_h2[:, None], avc2, my_avc)
    async_phase = jnp.where(go_h2, 2, async_phase)
    to_ac = alive & is_async & (async_phase == 2) & (cnt_h2 >= q)
    ac_pay = jnp.concatenate(
        [v_cur[:, None].astype(jnp.float32), my_r[:, None].astype(jnp.float32),
         my_avc], axis=1)[:, None, :] * jnp.ones((n, n, 1))
    sends.append(ch.Send("ac", ac_pay, delays,
                         to_ac[:, None] & jnp.ones((n, n), jnp.bool_)))
    async_phase = jnp.where(to_ac, 3, async_phase)

    # ---- 7) deliver <asynchronous-complete>; exit (Alg3 lines 24-36) ------
    acfl, acpay = msgs["ac"]
    ac_st = ch.fold_state(st["ac_st"], acfl, acpay)
    ac_arr = jnp.swapaxes(acfl, 0, 1)
    ac_v = ac_st[:, :, 0].astype(jnp.int32)
    newer = ac_arr & (ac_v > st["ac_v_seen"])
    ac_tick = jnp.where(newer, tf, st["ac_tick"])
    ac_v_seen = jnp.where(newer, ac_v, st["ac_v_seen"])
    acm = ac_v == v_cur[:, None]                          # matching this view
    ac_cnt = jnp.sum(acm, axis=1)
    exit_ = alive & is_async & (ac_cnt >= q) & (st["exited_view"] < v_cur)
    leader = st["coins"][jnp.clip(v_cur, 0, MAX_VIEWS - 1)]
    # first n-f rule: leader's ac among the q earliest arrival ticks
    tick_m = jnp.where(acm, ac_tick, jnp.inf)
    thr = jnp.sort(tick_m, axis=1)[:, q - 1]
    ldr_tick = jnp.take_along_axis(tick_m, leader[:, None], axis=1)[:, 0]
    ldr_in = jnp.take_along_axis(acm, leader[:, None], axis=1)[:, 0] \
        & (ldr_tick <= thr)
    ac_r = ac_st[:, :, 1].astype(jnp.int32)
    ldr_r = jnp.take_along_axis(ac_r, leader[:, None], axis=1)[:, 0]
    ldr_vc = jnp.take_along_axis(ac_st[:, :, 2:], leader[:, None, None], axis=1)[:, 0]
    do_commit = exit_ & ldr_in
    commit_key = jnp.where(do_commit,
                           jnp.maximum(commit_key, key(v_cur, ldr_r)), commit_key)
    cvc = jnp.where(do_commit[:, None], jnp.maximum(cvc, ldr_vc), cvc)
    bh_key = jnp.where(do_commit, key(v_cur, ldr_r), bh_key)
    bh_vc = jnp.where(do_commit[:, None], ldr_vc, bh_vc)
    # Bfall catch-up (lines 29-31): leader's height-2 seen via propose-async
    ldr_pa_v = jnp.take_along_axis(pa_v, leader[:, None], axis=1)[:, 0]
    ldr_pa_h = jnp.take_along_axis(pa_h, leader[:, None], axis=1)[:, 0]
    ldr_pa_r = jnp.take_along_axis(pa_r, leader[:, None], axis=1)[:, 0]
    ldr_pa_vc = jnp.take_along_axis(pa_st[:, :, 1:], leader[:, None, None], axis=1)[:, 0]
    bfall = exit_ & ~ldr_in & (ldr_pa_v == v_cur) & (ldr_pa_h == 2)
    bh_key = jnp.where(bfall, key(v_cur, ldr_pa_r), bh_key)
    bh_vc = jnp.where(bfall[:, None], ldr_pa_vc, bh_vc)
    exited_view = jnp.where(exit_, v_cur, st["exited_view"])
    r_cur = jnp.where(exit_, bh_key % RS, r_cur)
    v_cur = jnp.where(exit_, v_cur + 1, v_cur)
    is_async = is_async & ~exit_
    async_phase = jnp.where(exit_, 0, async_phase)
    deadline = jnp.where(exit_, tf + to_ticks, deadline)
    # vote to the next view's leader (line 35)
    ex_vote_pay = jnp.concatenate(
        [key(v_cur, r_cur)[:, None].astype(jnp.float32),
         bh_key[:, None].astype(jnp.float32), bh_vc], axis=1)[:, None, :] \
        * jnp.ones((n, n, 1))
    ex_vote_mask = exit_[:, None] & (jnp.arange(n)[None, :]
                                     == _leader_of(v_cur, n)[:, None])
    sends.append(ch.Send("vote", ex_vote_pay, delays, ex_vote_mask))

    ring = ch.ring_commit(spec, st["ring"], t, sends, drop=drop,
                          backend=cfg.channel_backend)

    # ---- flight recorder (repro.obs; absent => compiled out) --------------
    # st[...] still holds the tick-entry values here (locals were rebound,
    # the dict is only updated below), so the masks are true transitions.
    tr = st.get("tr")
    if tr is not None or "mon_io" in st:
        sent_any = sends[0].mask
        for s in sends[1:]:
            sent_any = sent_any | s.mask
        cut = jnp.sum(sent_any & drop, axis=1)
    if tr is not None:
        es = obs.DEFAULT_SPEC
        vchg = v_cur != st["v_cur"]
        tr = obs.record(es, tr, "view_change", vchg, t, a=v_cur, b=r_cur)
        tr = obs.record(es, tr, "leader_change", vchg, t,
                        a=_leader_of(v_cur, n), b=v_cur)
        # sync<->async transitions: a=1 entering the async path, 0 exiting
        tr = obs.record(es, tr, "mode_switch", is_async != st["is_async"],
                        t, a=is_async, b=v_cur)
        tr = obs.record(es, tr, "commit", commit_key > st["commit_key"], t,
                        a=commit_key, b=jnp.sum(cvc, axis=1))
        tr = obs.record_env(es, tr, alive, t, a=v_cur, b=r_cur,
                            dropped_links=cut)
        st["tr"] = tr
    if "mon_io" in st:
        st["mon_io"] = {"dropped": cut.astype(jnp.int32)}

    st.update(
        v_cur=v_cur, r_cur=r_cur, is_async=is_async, bh_key=bh_key,
        bh_vc=bh_vc.astype(jnp.int32), commit_key=commit_key,
        cvc=cvc.astype(jnp.int32), prop_key=prop_key,
        last_vote_trig=last_vote_trig, deadline=deadline,
        timeout_sent_v=timeout_sent_v, async_phase=async_phase, my_r=my_r,
        my_avc=my_avc.astype(jnp.int32), exited_view=exited_view,
        ac_tick=ac_tick, ac_v_seen=ac_v_seen, vote_st=vote_st, to_st=to_st,
        pa_st=pa_st, va_st=va_st, ac_st=ac_st, ring=ring)
    return st
