# One function per paper table/figure. Prints ``name,us_per_call,derived``.
"""Benchmark driver:
  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig6,roofline,...]

Figure suites dispatch through the batched experiment engine
(repro.core.experiment): each protocol's whole rate grid compiles once and
runs as a single vmapped program; the per-suite stderr line reports
wall-clock and the cumulative jit-trace count.

Every run also writes ``BENCH_core.json`` at the repo root — per-suite
wall-clock with the compile-vs-run split and the resolved channel-ring
horizon (experiment.timing_stats) — so the perf trajectory is tracked
across PRs; the ``channel`` suite's packed-vs-legacy comparison lands in
``benchmarks/artifacts/channel_bench.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks import figures  # noqa: E402
from benchmarks import roofline  # noqa: E402
from benchmarks.bench_kernels import bench as kernel_bench  # noqa: E402
from benchmarks.bench_kernels import bench_channel  # noqa: E402
from repro.core import experiment  # noqa: E402

REPO = Path(__file__).resolve().parents[1]


def _channel_suite() -> list:
    rows = bench_channel()
    art = {r[0]: {"us_per_tick": r[1], "derived": r[2]} for r in rows}
    (figures.ART / "channel_bench.json").write_text(
        json.dumps(art, indent=1))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter sims (2s instead of 4s)")
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()
    sim_s = 2.0 if args.quick else 4.0
    only = set(args.only.split(",")) if args.only else None

    figures.ART.mkdir(parents=True, exist_ok=True)
    suites = {
        "fig6": lambda: figures.fig6_throughput_latency(sim_s),
        "fig7": lambda: figures.fig7_crash(sim_s),
        "fig8": lambda: figures.fig8_ddos(sim_s),
        "fig9": lambda: figures.fig9_scalability(max(sim_s - 1, 2.0)),
        "robustness": lambda: figures.robustness(sim_s),
        "workload-matrix": lambda: figures.workload_matrix(sim_s),
        "paper": figures.paper_comparison,
        "kernels": kernel_bench,
        "channel": _channel_suite,
        "roofline_single": lambda: roofline.rows("single"),
        "roofline_multi": lambda: roofline.rows("multi"),
    }
    if only:
        unknown = only - suites.keys()
        if unknown:
            sys.exit(f"unknown suite(s): {', '.join(sorted(unknown))}; "
                     f"valid: {', '.join(suites)}")
    print("name,us_per_call,derived")
    errored = []
    bench_core: dict = {"suites": {}}
    for name, fn in suites.items():
        if only and name not in only:
            continue
        experiment.reset_timing_stats()
        t0 = time.time()
        suite_error = None
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            errored.append(name)
            suite_error = type(e).__name__
        wall = time.time() - t0
        stats = experiment.timing_stats()
        entry = {
            # per-suite so merged files can't mix quick/full timings
            # under one misleading top-level flag
            "quick": args.quick,
            "wall_s": round(wall, 2),
            # first-dispatch (trace+compile+first run) vs cache-hit split
            "compile_s": round(sum(s["compile_s"] for s in stats.values()),
                               2),
            "run_s": round(sum(s["run_s"] for s in stats.values()), 2),
        }
        if suite_error is not None:
            # a partial run's wall-clock is not a trajectory point —
            # mark it so cross-PR comparisons can filter it out
            entry["error"] = suite_error
        horizons = {p: s["horizon"] for p, s in stats.items()
                    if s.get("horizon")}
        if horizons:
            entry["ring_horizon"] = horizons
        bench_core["suites"][name] = entry
        traces = sum(experiment.trace_counts().values())
        print(f"# {name} done in {wall:.0f}s "
              f"(sweep traces so far: {traces})", file=sys.stderr)
    # merge into the tracked trajectory file: partial (--only) runs update
    # just the suites they ran instead of discarding the rest
    bench_path = REPO / "BENCH_core.json"
    if bench_path.exists():
        try:
            prev = json.loads(bench_path.read_text())
            merged = prev.get("suites", {})
            merged.update(bench_core["suites"])
            bench_core["suites"] = merged
        except (json.JSONDecodeError, AttributeError):
            pass
    bench_path.write_text(json.dumps(bench_core, indent=1) + "\n")
    roofline.main()
    if errored:
        sys.exit(f"suite(s) errored: {', '.join(errored)}")


if __name__ == "__main__":
    main()
