"""Lower a declarative Scenario to the array-native windowed env tables.

The union of every primitive's tick edges cuts the run into W maximal
windows over which all tables are constant; ``lower`` paints each primitive
onto the rows it covers (in Scenario order) and emits, as plain numpy:

  win_start[W]           first tick of each window (win_start[0] == 0)
  win_of_tick[n_ticks]   tick -> window row (precomputed, exact)
  alive[W, n], drop[W, n, n], extra_delay[W, n, n], nic_scale[W, n]

``netsim.build_env`` embeds these into the env dict; padding to a common
``n_windows`` (repeat-last-row, rows never read because ``win_of_tick``
only indexes real windows) is what lets heterogeneous scenarios stack
leaf-wise through ``netsim.stack_envs`` and vmap through
``experiment.run_sweep`` as one compiled program.

The seed-era ``netsim.FaultSchedule`` compiled to these same tables through
a (since-removed) shim; its exact semantics survive as primitives —
permanent ``Crash`` events and the random-minority ``TargetedDelay`` with
the seeded draw stream — still pinned bitwise against the seed-era
reference by tests/test_scenarios.py.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.smr import SMRConfig
from repro.scenarios.primitives import Scenario, Tables


def _sim_ticks(cfg: SMRConfig) -> int:
    # keep in sync with netsim.sim_ticks (not imported: scenarios sit below
    # core in the layering; netsim imports us lazily from build_env)
    return int(cfg.sim_seconds * 1000 / cfg.tick_ms)


def n_windows(cfg: SMRConfig, scenario: Scenario) -> int:
    """Window count of the lowered scenario (for cross-scenario padding)."""
    return len(_win_starts(cfg, scenario))


def _win_starts(cfg: SMRConfig, scenario: Scenario) -> np.ndarray:
    n_ticks = _sim_ticks(cfg)
    edges = {0}
    for ev in scenario.events:
        edges.update(int(e) for e in ev.edges(cfg, n_ticks))
    return np.array(sorted(e for e in edges if 0 <= e < n_ticks), np.int64)


_WINDOW_KEYS = ("alive", "drop", "extra_delay", "nic_scale")


def pad_tables(tab: Tables, pad_windows: int) -> Tables:
    """Repeat-last-row pad the [W, ...] window tables to a common width
    (padding rows are never read: ``win_of_tick`` only indexes real
    windows). ``win_start``/``win_of_tick`` pass through untouched."""
    w = tab["alive"].shape[0]
    if pad_windows < w:
        raise ValueError(f"pad_windows={pad_windows} < {w} real windows")
    pad = pad_windows - w
    return {k: (np.pad(v, ((0, pad),) + ((0, 0),) * (v.ndim - 1),
                       mode="edge") if k in _WINDOW_KEYS else v)
            for k, v in tab.items()}


def lower(cfg: SMRConfig, scenario: Scenario,
          pad_windows: Optional[int] = None) -> Tables:
    n = cfg.n_replicas
    n_ticks = _sim_ticks(cfg)
    win_start = _win_starts(cfg, scenario)
    w = len(win_start)
    tab: Tables = {
        "alive": np.ones((w, n), np.bool_),
        "drop": np.zeros((w, n, n), np.bool_),
        "extra_delay": np.zeros((w, n, n), np.float32),
        "nic_scale": np.ones((w, n), np.float32),
    }
    for ev in scenario.events:
        ev.paint(cfg, n_ticks, win_start, tab)
    tab["win_start"] = win_start
    tab["win_of_tick"] = (np.searchsorted(win_start, np.arange(n_ticks),
                                          side="right") - 1).astype(np.int32)
    if pad_windows is not None:
        tab = pad_tables(tab, pad_windows)
    return tab


def as_scenario(obj) -> Scenario:
    """Normalize None / Scenario to a Scenario."""
    if obj is None:
        return Scenario()
    if isinstance(obj, Scenario):
        return obj
    raise TypeError(f"expected Scenario or None, got {type(obj)}")
