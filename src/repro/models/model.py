"""Decoder LM composition: embed -> scan over super-blocks -> norm -> head.

A *super-block* is the smallest repeating period of layer kinds (dense: 1;
jamba: 8 [7 mamba + 1 attn, MoE every 2]; xlstm: 8 [7 mLSTM + 1 sLSTM];
vlm: 5 [4 self + 1 cross]). Parameters are stacked [R, ...] over repeats and
the decoder scans over R — HLO size stays O(period), not O(n_layers).

Entry points:
  init_params(cfg, key, dtype)
  forward_train(params, cfg, call, batch)        -> (logits, aux)
  init_cache(cfg, batch, max_seq, dtype)
  forward_decode(params, cfg, call, batch, cache, pos) -> (logits, cache)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.layers import (CallConfig, constrain_act, cross_attention,
                                 init_attention, init_mlp, rms_norm,
                                 self_attention, swiglu)
from repro.models.moe import init_moe, moe_mlp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, kind: str, has_moe: bool, has_cross: bool, key):
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": jnp.ones((cfg.d_model,))}
    if kind == "attn":
        p["mixer"] = init_attention(cfg, ks[0])
    elif kind == "mamba":
        p["mixer"] = ssm.init_mamba(cfg, ks[0])
    elif kind == "mlstm":
        p["mixer"] = ssm.init_mlstm(cfg, ks[0])
    elif kind == "slstm":
        p["mixer"] = ssm.init_slstm(cfg, ks[0])
    else:
        raise ValueError(kind)
    if has_cross:
        p["cross_norm"] = jnp.ones((cfg.d_model,))
        p["cross"] = init_attention(cfg, ks[1], cross=True)
    if has_moe:
        p["norm2"] = jnp.ones((cfg.d_model,))
        p["moe"] = init_moe(cfg, ks[2])
    elif cfg.d_ff:
        p["norm2"] = jnp.ones((cfg.d_model,))
        p["mlp"] = init_mlp(cfg, ks[2], cfg.d_ff)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    period = cfg.block_period
    repeats = cfg.n_layers // period
    kinds = cfg.layer_kinds()
    k_embed, k_head, k_blocks = jax.random.split(key, 3)
    p: Params = {"final_norm": jnp.ones((cfg.d_model,))}
    if cfg.embed_inputs:
        p["embed"] = jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(k_head, (cfg.d_model, cfg.vocab)) \
            * cfg.d_model ** -0.5

    def init_block(bkey):
        pos_keys = jax.random.split(bkey, period)
        return [
            _init_layer(cfg, kinds[i], cfg.layer_has_moe(i),
                        cfg.layer_has_cross_attn(i), pos_keys[i])
            for i in range(period)
        ]

    bkeys = jax.random.split(k_blocks, repeats)
    p["blocks"] = jax.vmap(init_block)(bkeys)      # leaves stacked [R, ...]
    return jax.tree.map(lambda a: a.astype(dtype), p)


def param_count_actual(params: Params) -> int:
    return sum(a.size for a in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ModelConfig, call: CallConfig, kind: str, lp: Params,
                 x: jax.Array, *, positions, mem, cache: Optional[dict],
                 max_seq: Optional[int], use_kernel_scan: bool
                 ) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    aux = jnp.float32(0.0)
    h = rms_norm(x, lp["norm1"], cfg.norm_eps, call)
    new_cache = None
    if kind == "attn":
        out, new_cache = self_attention(lp["mixer"], h, cfg=cfg, call=call,
                                        positions=positions, cache=cache,
                                        max_seq=max_seq)
    elif kind == "mamba":
        if cache is not None:
            out, new_cache = ssm.mamba_decode(lp["mixer"], h, cache, cfg=cfg)
        else:
            out = ssm.mamba_forward(lp["mixer"], h, cfg=cfg,
                                    use_kernel=use_kernel_scan)
    elif kind == "mlstm":
        if cache is not None:
            out, new_cache = ssm.mlstm_decode(lp["mixer"], h, cache, cfg=cfg)
        else:
            out = ssm.mlstm_forward(lp["mixer"], h, cfg=cfg)
    elif kind == "slstm":
        if cache is not None:
            out, new_cache = ssm.slstm_decode(lp["mixer"], h, cache, cfg=cfg)
        else:
            out = ssm.slstm_forward(lp["mixer"], h, cfg=cfg)
    else:
        raise ValueError(kind)
    x = x + out
    if "cross" in lp:
        hc = rms_norm(x, lp["cross_norm"], cfg.norm_eps, call)
        x = x + cross_attention(lp["cross"], hc, mem, cfg=cfg, call=call)
    if "moe" in lp:
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps, call)
        tok_axes = call.batch_axes + ((call.seq_axis,)
                                      if call.seq_axis else ())
        y, aux = moe_mlp(lp["moe"], h2, cfg=cfg, ep_axis=call.moe_ep_axis,
                         group_size=call.moe_group_size, tok_axes=tok_axes)
        x = x + y
    elif "mlp" in lp:
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps, call)
        x = x + swiglu(lp["mlp"], h2)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(params: Params, cfg: ModelConfig, call: CallConfig, batch: Dict):
    if cfg.embed_inputs:
        x = params["embed"][batch["tokens"]]
    else:
        x = batch["frame_emb"]
    mem = batch.get("vision_mem")
    return x.astype(call.compute_dtype), (
        None if mem is None else mem.astype(call.compute_dtype))


def _head(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)


def forward_train(params: Params, cfg: ModelConfig, call: CallConfig,
                  batch: Dict) -> Tuple[jax.Array, jax.Array]:
    """batch: tokens [B,S] (or frame_emb [B,S,D]), optional vision_mem [B,M,D].
    Returns (logits [B,S,V] fp32, aux_loss scalar)."""
    kinds = cfg.layer_kinds()[:cfg.block_period]
    x, mem = _embed(params, cfg, call, batch)
    x = constrain_act(x, call)
    positions = jnp.arange(x.shape[1])

    def block_body(x, block_params):
        aux = jnp.float32(0.0)
        for i, kind in enumerate(kinds):
            x, _, a = _apply_layer(cfg, call, kind, block_params[i], x,
                                   positions=positions, mem=mem, cache=None,
                                   max_seq=None, use_kernel_scan=False)
            x = constrain_act(x, call)
            aux = aux + a
        return x, aux

    body = block_body
    if call.remat:
        body = jax.checkpoint(block_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(x, bp):
        return body(x, bp)

    x, auxs = jax.lax.scan(scan_body, x, params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, call)
    return _head(params, cfg, x), jnp.sum(auxs)


def loss_fn(params: Params, cfg: ModelConfig, call: CallConfig,
            batch: Dict) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Vocab-shard-friendly cross entropy: every reduction over V is a
    partial-sum + tiny all-reduce under SPMD — the full [B,S,V] logits are
    never gathered onto one device (the head is TP-sharded on V)."""
    logits, aux = forward_train(params, cfg, call, batch)
    labels = batch["labels"]
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.sum(jnp.where(iota == labels[..., None], shifted, 0.0),
                     axis=-1)
    nll = lse - picked
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    nll = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    zloss = 1e-4 * jnp.mean((lse + m[..., 0]) ** 2)
    total = nll + aux + zloss
    return total, {"nll": nll, "aux": aux, "zloss": zloss}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> list:
    """Per super-block-position state, stacked over repeats R."""
    period = cfg.block_period
    repeats = cfg.n_layers // period
    kinds = cfg.layer_kinds()[:period]

    def one(kind):
        if kind == "attn":
            return {
                "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
            }
        if kind == "mamba":
            return ssm.mamba_init_state(cfg, batch, dtype)
        if kind == "mlstm":
            return ssm.mlstm_init_state(cfg, batch, dtype)
        if kind == "slstm":
            return ssm.slstm_init_state(cfg, batch, dtype)
        raise ValueError(kind)

    return [jax.tree.map(lambda a: jnp.broadcast_to(a, (repeats,) + a.shape),
                         one(k)) for k in kinds]


def forward_decode(params: Params, cfg: ModelConfig, call: CallConfig,
                   batch: Dict, cache: list, pos: jax.Array
                   ) -> Tuple[jax.Array, list]:
    """One decode step. batch: tokens [B] (or frame_emb [B,1,D]), optional
    vision_mem. pos: scalar int32 — the position being written.
    Returns (logits [B,V] fp32, new cache)."""
    kinds = cfg.layer_kinds()[:cfg.block_period]
    if cfg.embed_inputs:
        x = params["embed"][batch["tokens"][:, None]]
    else:
        x = batch["frame_emb"]
    x = x.astype(call.compute_dtype)
    mem = batch.get("vision_mem")
    if mem is not None:
        mem = mem.astype(call.compute_dtype)
    positions = pos.astype(jnp.int32)

    def scan_body(x, xs):
        block_params, block_cache = xs
        new_cache = []
        for i, kind in enumerate(kinds):
            x, nc, _ = _apply_layer(cfg, call, kind, block_params[i], x,
                                    positions=positions, mem=mem,
                                    cache=block_cache[i], max_seq=None,
                                    use_kernel_scan=False)
            new_cache.append(nc)
        return x, new_cache

    x, new_cache = jax.lax.scan(scan_body, x, (params["blocks"], cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, call)
    logits = _head(params, cfg, x)[:, 0]
    return logits, new_cache
