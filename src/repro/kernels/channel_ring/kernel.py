"""Fused channel-ring commit as a Pallas-TPU kernel.

XLA lowers the oracle's scatters (ref.py) to serialized scatter ops — fine
on CPU, slow on TPU. This kernel re-expresses the whole tick as a *dense*
pass over the ring instead: the grid tiles the slot axis, each step holds a
``[bs, n, n, K]`` block of the packed ring in VMEM and

  - resets the delivered slot ``t % D`` to the fill vector,
  - for every send entry (static python loop — the per-tick send list of a
    protocol is a static, short sequence) compares the entry's target-slot
    matrix against the block's slot ids and max/add-merges the masked
    payload and flag contributions in registers.

Work is O(D * n^2 * K) dense VPU ops per tick — with the auto-sized delay
horizon (netsim.resolve_horizon) D is a few hundred, so the whole ring is a
handful of VMEM tiles and the pass is bandwidth-bound with zero scatter
serialization. Contributions use the merge-neutral element (NEG / 0.0)
outside the target slot, so the result is bitwise identical to the oracle.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# static per-entry layout: (payload offset, width, flag field, additive)
EntryLayout = Tuple[int, int, int, bool]

NEG = -1.0  # "absent" payload fill of max-merged channels (channel.NEG)


def _commit_kernel(buf_ref, fill_ref, t_ref, *refs, bs: int, d: int,
                   layout: Sequence[EntryLayout]):
    n_entries = len(layout)
    slot_refs = refs[:n_entries]
    val_refs = refs[n_entries:2 * n_entries]
    flag_refs = refs[2 * n_entries:3 * n_entries]
    out_ref = refs[3 * n_entries]

    i = pl.program_id(0)
    # slot ids of this block, [bs, 1, 1] (TPU iota must be >= 2D)
    s = i * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1, 1), 0)
    blk = buf_ref[...]                                   # [bs, n, n, K]
    # slot-clear of the tick's delivered slot
    is_t = (s == t_ref[0] % d)[..., None]                # [bs, 1, 1, 1]
    blk = jnp.where(is_t, fill_ref[...][None, None, None, :], blk)
    for (off, w, flag_off, additive), sr, vr, fr in zip(
            layout, slot_refs, val_refs, flag_refs):
        hit = (sr[...][None, :, :] == s)                 # [bs, n, n]
        vals = vr[...][None, :, :, :]                    # [1, n, n, w]
        if additive:
            contrib = jnp.where(hit[..., None], vals, 0.0)
            blk = blk.at[:, :, :, off:off + w].add(contrib)
        else:
            contrib = jnp.where(hit[..., None], vals, NEG)
            blk = blk.at[:, :, :, off:off + w].max(contrib)
        fl = jnp.where(hit, fr[...][None, :, :], 0.0)    # [bs, n, n]
        blk = blk.at[:, :, :, flag_off].max(fl)
    out_ref[...] = blk


def ring_commit_tpu(buf: jax.Array, t: jax.Array, fill: jax.Array,
                    slots: Sequence[jax.Array], vals: Sequence[jax.Array],
                    flags: Sequence[jax.Array],
                    layout: Sequence[EntryLayout], *, bs: int = 256,
                    interpret: bool = False) -> jax.Array:
    """buf: [D, n, n, K]; t: scalar int32; fill: [K]; per send entry e:
    slots[e]: [n, n] int32 target slot, vals[e]: [n, n, w_e] merged payload,
    flags[e]: [n, n] flag contribution (1.0 where the send mask is set)."""
    d, n, _, k = buf.shape
    bs = min(bs, d)
    while d % bs:
        bs //= 2
    # lint: allow(traced-purity): coercing the static EntryLayout to
    # hashable Python ints for pallas_call closure — trace-time only
    layout = tuple((int(o), int(w), int(f), bool(a)) for o, w, f, a in layout)
    kernel = functools.partial(_commit_kernel, bs=bs, d=d, layout=layout)
    buf_spec = pl.BlockSpec((bs, n, n, k), lambda i: (i, 0, 0, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda i, _s=shape:  # noqa: E731
                                      (0,) * len(_s))
    in_specs = ([buf_spec, full((k,)),
                 pl.BlockSpec(memory_space=pltpu.SMEM)]
                + [full(s.shape) for s in slots]
                + [full(v.shape) for v in vals]
                + [full(f.shape) for f in flags])
    return pl.pallas_call(
        kernel,
        grid=(d // bs,),
        in_specs=in_specs,
        out_specs=buf_spec,
        out_shape=jax.ShapeDtypeStruct(buf.shape, buf.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(buf, fill, jnp.reshape(t, (1,)).astype(jnp.int32),
      *slots, *vals, *flags)
