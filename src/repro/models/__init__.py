from repro.models.layers import CallConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    forward_decode, forward_train, init_cache, init_params, loss_fn,
    param_count_actual,
)
