"""Sharded checkpointing with Mandator-style asynchronous commit.

Data plane: each controller streams its parameter/optimizer shards to
storage *ahead of* any commit decision (write(B) of Algorithm 1 — shard
round files are the Mandator-batches). Control plane: a checkpoint version
is a **vector-clock cut** over controller shard rounds; the tiny
``commit-<v>.json`` manifest is written only once a quorum of shard writes
is durable (n-f votes). Restore picks the highest committed cut — torn
checkpoints (some shards newer) are impossible by construction, which is
exactly Mandator's availability property applied to storage.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in leaves_p:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class MandatorCheckpointer:
    """n_controllers shard-writers + quorum commit. In production each
    controller is one pod's host fleet; here they are invoked in-process
    (the protocol logic is identical — see runtime/sporades_rt.py for the
    fallback path when controllers fail)."""

    def __init__(self, root: str | Path, n_controllers: int = 1):
        self.root = Path(root)
        self.n = n_controllers
        self.f = (n_controllers - 1) // 2
        self.root.mkdir(parents=True, exist_ok=True)

    # ---- data plane -------------------------------------------------------
    def write_shard(self, controller: int, version: int,
                    tree: Any, tag: str = "state") -> bool:
        """One controller's shard write (Mandator write(B)). Returns ack."""
        d = self.root / f"c{controller}" / f"v{version}"
        d.mkdir(parents=True, exist_ok=True)
        flat = _flatten(tree)
        np.savez(d / f"{tag}.npz", **flat)
        (d / f"{tag}.ok").write_text(str(time.time()))
        return True

    # ---- control plane ----------------------------------------------------
    def try_commit(self, version: int, step: int,
                   acks: Optional[List[bool]] = None) -> bool:
        """Commit the cut if >= n-f controller shards are durable."""
        present = []
        for c in range(self.n):
            ok = (self.root / f"c{c}" / f"v{version}" / "state.ok").exists()
            if acks is not None:
                ok = ok and acks[c]
            present.append(ok)
        if sum(present) < self.n - self.f:
            return False
        manifest = {"version": version, "step": step,
                    "controllers": [c for c, p in enumerate(present) if p],
                    "time": time.time()}
        (self.root / f"commit-{version}.json").write_text(
            json.dumps(manifest))
        return True

    def latest_committed(self) -> Optional[Dict]:
        best = None
        for p in self.root.glob("commit-*.json"):
            m = json.loads(p.read_text())
            if best is None or m["version"] > best["version"]:
                best = m
        return best

    def restore(self, template: Any, controller: int = 0,
                tag: str = "state") -> Optional[Tuple[int, Any]]:
        m = self.latest_committed()
        if m is None:
            return None
        src = controller if controller in m["controllers"] \
            else m["controllers"][0]
        d = self.root / f"c{src}" / f"v{m['version']}"
        flat = dict(np.load(d / f"{tag}.npz"))
        return m["step"], _unflatten(template, flat)


def save(path: str | Path, step: int, params: Any, opt_state: Any) -> None:
    """Single-writer convenience wrapper (quickstart / tests)."""
    ck = MandatorCheckpointer(path, 1)
    ck.write_shard(0, step, {"params": params, "opt": opt_state})
    ck.try_commit(step, step)


def restore(path: str | Path, params_tmpl: Any, opt_tmpl: Any
            ) -> Optional[Tuple[int, Any, Any]]:
    ck = MandatorCheckpointer(path, 1)
    out = ck.restore({"params": params_tmpl, "opt": opt_tmpl})
    if out is None:
        return None
    step, tree = out
    return step, tree["params"], tree["opt"]
