"""End-to-end trainer: data pipeline -> train_step -> Mandator/Sporades
control plane -> checkpoints. CPU-runnable with reduced configs; the same
driver jit-compiles against the production mesh on real hardware.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 200 --batch 8 --seq 64 --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import MandatorCheckpointer
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, batch_shard
from repro.distributed.steps import make_train_step
from repro.models import CallConfig, init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.elastic import grad_scale, replan
from repro.runtime.mandator_rt import MandatorRuntime
from repro.runtime.sporades_rt import SporadesRuntime


def train(arch: str, *, reduced: bool = True, steps: int = 100,
          batch: int = 8, seq: int = 64, n_pods: int = 1,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          lr: float = 1e-3, log_every: int = 10, seed: int = 0,
          crash_pod_at: Optional[int] = None, verbose: bool = True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", "train", seq, batch)
    call = CallConfig(compute_dtype=jnp.float32, attention_impl="dense",
                      remat=False)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=20)
    dcfg = DataConfig(seed=seed)

    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, call, opt_cfg))

    # control plane: one Mandator chain + Sporades commit per pod controller
    mand = MandatorRuntime(n_pods)
    spor = SporadesRuntime(n_pods, seed=seed)
    ck = MandatorCheckpointer(ckpt_dir, n_pods) if ckpt_dir else None

    start_step = 0
    if ck is not None:
        restored = ck.restore({"params": params, "opt": opt_state})
        if restored is not None:
            start_step, tree = restored
            params, opt_state = tree["params"], tree["opt"]
            if verbose:
                print(f"[restore] resumed at step {start_step}")

    live = list(range(n_pods))
    losses = []
    for step in range(start_step, steps):
        if crash_pod_at is not None and step == crash_pod_at and n_pods > 1:
            spor.crash(n_pods - 1)
            live = live[:-1]
            if verbose:
                print(f"[fault] pod {n_pods-1} crashed at step {step}; "
                      f"elastic replan to {len(live)} pods")
        plan = replan(step, live)
        # each live pod computes grads on its shard; here pods execute
        # sequentially in-process (one jit step per pod shard)
        scale = grad_scale(len(live), n_pods)
        pod_metrics = []
        for pod in plan.pods:
            b = batch_shard(cfg, shape, dcfg, step, plan.shard_of[pod],
                            plan.n_shards)
            params, opt_state, m = step_fn(params, opt_state, b)
            pod_metrics.append(m)
            mand.write(pod)                    # artifact round disseminated
        # commit the step cut (sync path; async under faults)
        cuts = {p: mand.get_client_requests(p) for p in plan.pods}
        rec = spor.commit_step(cuts)
        loss = float(np.mean([float(m["loss"]) for m in pod_metrics]))
        losses.append(loss)
        if verbose and (step % log_every == 0 or step == steps - 1):
            mode = rec.mode if rec else "none"
            print(f"step {step:4d} loss {loss:8.4f} "
                  f"gnorm {float(pod_metrics[0]['grad_norm']):7.3f} "
                  f"commit={mode} scale={scale:.2f}")
        if ck is not None and (step + 1) % ckpt_every == 0:
            for pod in plan.pods:
                ck.write_shard(pod, step + 1,
                               {"params": params, "opt": opt_state})
            ck.try_commit(step + 1, step + 1)
    return {"losses": losses, "params": params, "opt_state": opt_state,
            "commits": [len(c.committed) for c in spor.ctl]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    out = train(args.arch, reduced=args.reduced, steps=args.steps,
                batch=args.batch, seq=args.seq, n_pods=args.pods,
                ckpt_dir=args.ckpt, lr=args.lr)
    print(f"final loss: {out['losses'][-1]:.4f} "
          f"(first: {out['losses'][0]:.4f})")


if __name__ == "__main__":
    main()
