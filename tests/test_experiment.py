"""Batched experiment engine (core/experiment.py): a vmapped sweep grid must
compile at most once per protocol (never per grid point) and produce
bitwise-identical metrics to the equivalent sequence of single run_sim
calls (same seeds/scenarios)."""
import numpy as np
import pytest

from repro.configs.smr import SMRConfig
from repro.core import experiment
from repro.core.experiment import SweepSpec, run_sweep
from repro.core.harness import run_sim
from repro.scenarios import Crash, Scenario, TargetedDelay

CFG = SMRConfig(sim_seconds=1.0)
SCALARS = ("throughput", "median_ms", "p99_ms", "committed")


def _assert_point_equal(batched, single):
    for k in SCALARS:
        b, s = batched[k], single[k]
        assert (b == s) or (np.isnan(b) and np.isnan(s)), \
            f"{k}: batched {b} != sequential {s}"
    np.testing.assert_array_equal(batched["timeline"], single["timeline"])


@pytest.mark.parametrize("protocol", ["mandator-sporades", "multipaxos"])
def test_grid_matches_sequential_run_sim(protocol):
    """Fig-6-style grid (3 rates x 2 seeds) through one vmapped dispatch ==
    six sequential single-point runs, bit for bit."""
    spec = SweepSpec(rates=(10_000, 20_000, 40_000), seeds=(0, 1))
    experiment.reset_trace_counts()
    grid = run_sweep(protocol, CFG, spec)
    # 0 = this shape's canonical program was already built earlier in the
    # process (the program store shares it); the guarantee under test is
    # that a grid NEVER builds one program per point
    assert experiment.trace_counts().get(protocol, 0) <= 1, \
        "a whole grid must compile as at most ONE program"
    assert len(grid) == spec.size == 6
    for r, (rate, seed, _, _) in zip(grid, spec.points()):
        assert (r["rate"], r["seed"]) == (rate, seed)
        _assert_point_equal(r, run_sim(protocol, CFG, rate_tx_s=rate,
                                       seed=seed))


def test_scenario_variants_stack_into_one_program():
    """Heterogeneous scenarios (none / crash / DDoS) batch through the
    stacked-env path and still match their single-point runs. The DDoS
    variant also forces the sweep-wide auto horizon (1024 >> the crash
    variants' standalone bound), so this pins that a shared ring size
    keeps every point bitwise equal to its own single run."""
    scenarios = (None,
                 Scenario("crash", (Crash(start_s=0.5, targets=(0,)),)),
                 Scenario("ddos", (TargetedDelay(
                     delay_ms=800.0, targets="random-minority",
                     repick_s=0.5, seed=7),)))
    spec = SweepSpec(rates=(20_000,), scenarios=scenarios)
    experiment.reset_trace_counts()
    grid = run_sweep("mandator-sporades", CFG, spec)
    assert experiment.trace_counts().get("mandator-sporades", 0) <= 1
    for r, (rate, seed, fi, _) in zip(grid, spec.points()):
        single = run_sim("mandator-sporades", CFG, rate_tx_s=rate,
                         scenario=scenarios[fi], seed=seed)
        _assert_point_equal(r, single)
        np.testing.assert_array_equal(r["cvc_all"], single["cvc_all"])


def test_analytic_baselines_share_the_sweep_api():
    rows = run_sweep("epaxos", SMRConfig(sim_seconds=5.0),
                     SweepSpec(rates=(5_000, 10_000)))
    assert [r["rate"] for r in rows] == [5_000, 10_000]
    assert rows[1]["throughput"] > 0


def test_unknown_protocol_rejected():
    with pytest.raises(ValueError):
        run_sweep("zab", CFG, SweepSpec(rates=(1_000,)))
