"""Host-side flight-recorder decoding: device trace state -> per-replica
timelines.

The on-device ring (obs/trace.py) keeps the newest ``cap`` events with the
write pointer free-running, so decoding unwraps modulo the capacity:
with ``ptr <= cap`` the valid entries are ``buf[:ptr]`` in order; past
that the ring holds the last ``cap`` events starting at the oldest slot
``ptr % cap``. Counters and the saturating ``dropped`` count come along
verbatim.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.obs.trace import DEFAULT_SPEC, FIELDS, PHASES, TraceSpec


def decode_ring(ts: Dict, spec: TraceSpec = DEFAULT_SPEC) -> List[Dict]:
    """One layer's trace state (numpy-able leaves, shapes as produced by a
    single sweep point) -> per-replica dicts:

      {"events": [{"name", "tick", "args": {a_name: a, b_name: b}}, ...],
       "counts": {event_name: int, ...},
       "dropped": int}

    ``events`` is oldest-to-newest and absent at TraceLevel.COUNTERS.
    """
    counts = np.asarray(ts["counts"])
    n = counts.shape[0]
    out: List[Dict] = []
    buf = np.asarray(ts["buf"]) if "buf" in ts else None
    ptr = np.asarray(ts["ptr"]) if buf is not None else None
    dropped = np.asarray(ts["dropped"]) if buf is not None else None
    ki, ti, ai, bi = (FIELDS.index(f) for f in ("kind", "tick", "a", "b"))
    for i in range(n):
        rep: Dict = {"counts": {name: int(counts[i, k])
                                for k, name in enumerate(spec.names)}}
        if buf is not None:
            cap = buf.shape[1]
            p = int(ptr[i])
            if p <= cap:
                order = buf[i, :p]
            else:
                s = p % cap
                order = np.concatenate([buf[i, s:], buf[i, :s]])
            events = []
            for rec in order:
                kind = int(rec[ki])
                name = spec.names[kind]
                an, bn = spec.args_of(kind)
                events.append({"name": name, "tick": int(rec[ti]),
                               "args": {an: int(rec[ai]),
                                        bn: int(rec[bi])}})
            rep["events"] = events
            rep["dropped"] = int(dropped[i])
        out.append(rep)
    return out


def decode_result(result: Dict,
                  spec: TraceSpec = DEFAULT_SPEC) -> Optional[Dict]:
    """Decode every layer ring of one sweep-point result (the ``obs`` key
    harness.sim_point emits when tracing): {layer: [per-replica dicts]}.
    None when the point was run without tracing."""
    obs = result.get("obs")
    if not obs:
        return None
    return {layer: decode_ring(ts, spec) for layer, ts in obs.items()}


def weighted_quantile(vals, weights, q: float) -> float:
    """Numpy twin of harness._weighted_quantile, for the host-side
    analytic baselines (epaxos/rabia phase accounting)."""
    vals = np.asarray(vals, float)
    weights = np.asarray(weights, float)
    if vals.size == 0 or weights.sum() <= 0:
        return float("nan")
    order = np.argsort(vals)
    v, w = vals[order], weights[order]
    cdf = np.cumsum(w) / w.sum()
    return float(v[min(np.searchsorted(cdf, q, side="left"), len(v) - 1)])


def host_phases(per_phase_ms: Dict[str, np.ndarray],
                weights) -> Dict[str, np.ndarray]:
    """Per-phase med/p99 arrays (obs.PHASES order) from host-side phase
    samples — the analytic models' counterpart of harness._phase_breakdown,
    so ``export.phases_dict`` reads every protocol uniformly."""
    med = [weighted_quantile(per_phase_ms.get(ph, ()), weights, 0.5)
           for ph in PHASES]
    p99 = [weighted_quantile(per_phase_ms.get(ph, ()), weights, 0.99)
           for ph in PHASES]
    return {"phase_med_ms": np.asarray(med),
            "phase_p99_ms": np.asarray(p99)}


def event_summary(decoded: Dict) -> Dict[str, Dict[str, int]]:
    """Cluster-wide event totals per layer: {layer: {event: count}}."""
    out: Dict[str, Dict[str, int]] = {}
    for layer, reps in decoded.items():
        tot: Dict[str, int] = {}
        for rep in reps:
            for name, c in rep["counts"].items():
                if c:
                    tot[name] = tot.get(name, 0) + c
        out[layer] = tot
    return out
