"""Kernel micro-benchmarks: wall time of the pure-jnp oracle path on CPU
(the Pallas kernels target TPU; interpret-mode timing is not meaningful, so
we time the XLA fallback the models actually run on this host and record
the kernels' analytic VMEM working sets as `derived`)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]


def _time(fn, *args, reps: int = 5) -> float:
    # warm up (compile) exactly once; block_until_ready handles pytrees
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def bench() -> List[Row]:
    from repro.core import compile_cache
    compile_cache.ensure()   # microbench compiles hit the persistent cache
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)

    # flash attention (chunked jnp path vs dense)
    from repro.models.layers import chunked_attention, dense_attention
    b, s, h, kh, d = 1, 1024, 8, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32)
    f_dense = jax.jit(lambda q, k, v: dense_attention(q, k, v, causal=True))
    f_chunk = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True,
                                                        chunk=128))
    t1 = _time(f_dense, q, k, v)
    t2 = _time(f_chunk, q, k, v)
    vmem_kb = (128 * d * 2 * 2 + 128 * 128 * 4) / 1024
    rows.append(("kernel/attention_dense_1k", t1, f"impl=dense;s={s}"))
    rows.append(("kernel/attention_flash_1k", t2,
                 f"impl=chunked;s={s};kernel_vmem_kb={vmem_kb:.0f}"))

    # rmsnorm fused
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    x = jax.random.normal(key, (4096, 1024))
    w = jnp.ones((1024,))
    f_norm = jax.jit(lambda x, w: rmsnorm_ref(x, w))
    rows.append(("kernel/rmsnorm_4096x1024", _time(f_norm, x, w),
                 "bytes_per_row=8192"))

    # ssm scan (chunked jnp path == what the dry run lowers)
    from repro.models.ssm import mamba_ssm
    bt, st_, di, n = 1, 2048, 512, 16
    ks = jax.random.split(key, 6)
    xs = jax.random.normal(ks[0], (bt, st_, di)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, st_, di)) - 1)
    B = jax.random.normal(ks[2], (bt, st_, n))
    C = jax.random.normal(ks[3], (bt, st_, n))
    A = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.3)
    D = jax.random.normal(ks[5], (di,))
    f_ssm = jax.jit(lambda *a: mamba_ssm(*a, chunk=128))
    rows.append(("kernel/ssm_scan_2048x512", _time(f_ssm, xs, dt, B, C, A, D),
                 f"state_vmem_kb={256*n*4/1024:.0f}"))
    return rows


def _channel_setup(n: int = 5):
    """Shared inputs of the channel microbench: sporades-shaped ring spec
    plus deterministic payload/delay/mask tensors (fixed PRNG keys, so the
    timed programs and the roofline HLO analysis lower the same bytes)."""
    from repro.core import sporades

    spec = sporades.ring_spec(n)
    widths = [(c.name, c.width) for c in spec.channels]
    key = jax.random.PRNGKey(0)
    delays = jax.random.randint(jax.random.PRNGKey(1), (n, n), 1, 170
                                ).astype(jnp.int32)
    payloads = {name: jax.random.uniform(jax.random.fold_in(key, i),
                                         (n, n, w), jnp.float32, 0.0, 9.0)
                for i, (name, w) in enumerate(widths)}
    mask = jnp.ones((n, n), jnp.bool_)
    return spec, widths, payloads, delays, mask


def packed_loop_fn(dmax: int = 256, n: int = 5, ticks: int = 200):
    """The packed-ring tick loop as a no-arg jittable callable — the
    channel microbench's packed path; benchmarks/roofline.py lowers the
    same callable for the HLO cost + roofline block."""
    from repro.core import channel as ch

    spec, widths, payloads, delays, mask = _channel_setup(n)

    def loop():
        ring = ch.make_ring(spec, dmax, n)

        def step(carry, t):
            msgs = ch.ring_deliver(spec, carry, t)
            out = sum(jnp.sum(p) + jnp.sum(f) for f, p in msgs.values())
            sends = [ch.Send(name, payloads[name], delays, mask)
                     for name, _ in widths]
            # "auto" = what the simulator dispatches: Pallas kernel on
            # TPU, jnp scatter oracle elsewhere
            return ch.ring_commit(spec, carry, t, sends,
                                  backend="auto"), out

        return jax.lax.scan(step, ring, jnp.arange(ticks, dtype=jnp.int32))

    return loop


def bench_channel(ticks: int = 200) -> List[Row]:
    """Packed channel ring vs the seed per-channel substrate: one scanned
    tick loop of sporades-shaped traffic (6 channels, broadcast sends) per
    substrate, at the auto-resolved baseline horizon and the seed-era 2048.
    Rows report us per simulated tick; run.py also drops the comparison
    into benchmarks/artifacts/channel_bench.json."""
    from repro.core import channel as ch
    from repro.core import compile_cache

    compile_cache.ensure()   # microbench compiles hit the persistent cache
    n = 5
    spec, widths, payloads, delays, mask = _channel_setup(n)

    def legacy_loop(dmax):
        chans = {name: ch.make_channel(dmax, n, w) for name, w in widths}

        def step(carry, t):
            out = 0.0
            new = {}
            for name, _ in widths:
                c, fl, pay = ch.deliver(carry[name], t)
                c = ch.send(c, t, payloads[name], delays, mask)
                out = out + jnp.sum(pay) + jnp.sum(fl)
                new[name] = c
            return new, out

        return jax.lax.scan(step, chans, jnp.arange(ticks, dtype=jnp.int32))

    rows: List[Row] = []
    for dmax in (256, 2048):
        t_leg = _time(jax.jit(lambda d=dmax: legacy_loop(d))) / ticks
        t_pak = _time(jax.jit(packed_loop_fn(dmax, n, ticks))) / ticks
        rows.append((f"channel/legacy_D{dmax}", t_leg,
                     f"substrate=per-channel;n={n};channels={len(widths)}"))
        rows.append((f"channel/packed_D{dmax}", t_pak,
                     f"substrate=packed-ring;n={n};K={spec.k};"
                     f"speedup={t_leg / t_pak:.2f}x"))
    return rows
