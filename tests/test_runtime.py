"""Training-control-plane tests: Mandator vector clocks under drops,
Sporades dual-mode commit under crashes/stragglers, elastic replans,
checkpoint commit cuts, optimizer + compression."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # degrade: only property tests skip
    HAVE_HYPOTHESIS = False

from repro.runtime.elastic import StragglerPolicy, grad_scale, replan
from repro.runtime.mandator_rt import MandatorRuntime
from repro.runtime.sporades_rt import SporadesRuntime


def test_mandator_rt_completion_and_vc():
    m = MandatorRuntime(5)
    for pod in range(5):
        r = m.write(pod)
        assert r == 1
    for pod in range(5):
        assert m.pods[pod].own_round == 1
        assert not m.pods[pod].awaiting
    # Alg 1: peers learn round r's completion from the round r+1 batch
    for pod in range(5):
        assert m.write(pod) == 2
    vc = m.get_client_requests(0)
    assert (vc >= 1).all() and vc[0] == 2


def test_mandator_rt_tolerates_minority_drops():
    m = MandatorRuntime(5)
    m.drop[0, 3] = m.drop[0, 4] = True     # 0's batches lost to 3 and 4
    assert m.write(0) == 1
    assert m.pods[0].own_round == 1        # quorum {0,1,2} suffices
    # replicas 3/4 haven't seen it
    assert m.pods[3].lcr[0] == 0
    # majority has: availability via quorum intersection
    assert sum(m.pods[j].lcr[0] >= 0 for j in (0, 1, 2)) == 3


def test_mandator_rt_blocks_without_quorum():
    m = MandatorRuntime(5)
    m.drop[0, 1:] = True                   # 0's batches reach nobody
    m.write(0)
    assert m.pods[0].awaiting               # never completes
    assert m.pods[0].own_round == 0


def test_sporades_rt_sync_path():
    s = SporadesRuntime(4)
    cuts = {i: np.array([1, 1, 1, 1]) for i in range(4)}
    rec = s.commit_step(cuts)
    assert rec is not None and rec.mode == "sync"


def test_sporades_rt_async_fallback_on_leader_straggle():
    s = SporadesRuntime(4, seed=1)
    s.set_straggler(s.leader(0))
    committed = 0
    for step in range(8):
        cuts = {i: np.array([step] * 4) for i in range(4)
                if s.ctl[i].alive}
        rec = s.commit_step(cuts)
        if rec is not None:
            assert rec.mode in ("sync", "async")
            committed += 1
    assert committed >= 4      # coin succeeds w.p. > 1/2 per round


def test_sporades_rt_no_quorum_blocks():
    s = SporadesRuntime(5)
    for i in (1, 2, 3):
        s.crash(i)
    rec = s.commit_step({0: np.zeros(5), 4: np.zeros(5)})
    assert rec is None


def test_sporades_rt_crash_then_recover():
    # seed=2: the view-1 coin elects live pod 1, so the fallback actually
    # commits async (seed=0 elects the crashed pod 0 — the fallback then
    # only advances the view and sync resumes without any async commit).
    s = SporadesRuntime(3, seed=2)
    s.crash(0)                               # leader of view 0 dead
    got = []
    for step in range(6):
        cuts = {i: np.array([step] * 3) for i in (1, 2)}
        got.append(s.commit_step(cuts))
    assert any(r is not None and r.mode == "async" for r in got)
    s.recover(0)
    cuts = {i: np.array([9] * 3) for i in range(3)}
    # once a view with a live leader arrives, sync path resumes
    for _ in range(4):
        rec = s.commit_step(cuts)
        if rec is not None and rec.mode == "sync":
            break
    else:
        pytest.fail("sync path never resumed after recovery")


def test_elastic_replan_deterministic():
    a = replan(10, [0, 2, 3])
    b = replan(10, [3, 2, 0])
    assert a == b
    assert a.n_shards == 3
    assert sorted(a.shard_of.values()) == [0, 1, 2]
    assert grad_scale(3, 4) == pytest.approx(4 / 3)


def test_straggler_policy():
    p = StragglerPolicy(deadline_ms=100.0)
    on_time, fb = p.decide({0: 10, 1: 20, 2: 30, 3: 40}, 4)
    assert not fb and len(on_time) == 4
    on_time, fb = p.decide({0: 10, 1: 20, 2: 30, 3: 400}, 4)
    assert fb and on_time == [0, 1, 2]
    # below quorum: wait for everyone
    on_time, fb = p.decide({0: 10, 1: 400, 2: 500, 3: 600}, 4)
    assert fb and len(on_time) == 4


def _commit_needs_quorum_case(n, dead):
    s = SporadesRuntime(n, seed=3)
    for d in dead:
        s.crash(d)
    live = [i for i in range(n) if i not in dead]
    cuts = {i: np.zeros(n) for i in live}
    rec = s.commit_step(cuts)
    f = (n - 1) // 2
    if len(live) < n - f:
        assert rec is None           # never commits without a quorum
    if rec is not None:
        assert len(live) >= n - f


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 9), st.data())
    def test_sporades_rt_commit_needs_quorum_property(n, data):
        dead = data.draw(st.sets(st.integers(0, n - 1), max_size=n))
        _commit_needs_quorum_case(n, dead)
else:
    def test_sporades_rt_commit_needs_quorum_property():
        """Degraded fixed-case variant (hypothesis not installed —
        pip install -r requirements-dev.txt for the property test)."""
        for n, dead in ((5, set()), (5, {0, 1, 2}), (3, {0, 1}), (9, {4})):
            _commit_needs_quorum_case(n, dead)
