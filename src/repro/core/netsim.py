"""WAN network environment: per-pair delays, NIC egress serialization,
crash faults, and targeted-minority DDoS (the §5.5 generalized
delayed-view-change attack).

``build_env`` is fully array-native: every leaf of the returned dict is a
fixed-shape ``jnp`` array (no Python scalars), so environments built from
different ``FaultSchedule`` variants can be stacked leaf-wise
(``stack_envs``) and the whole tick loop vmapped over the stacked axis by
the batched experiment engine (core/experiment.py). Pass ``n_windows`` to
pad the DDoS window table to a common width before stacking; padding rows
are never read because the window index stays below ``ddos_windows`` for
every simulated tick.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.smr import SMRConfig


@dataclass(frozen=True)
class FaultSchedule:
    """crash_time_s[i] — replica i stops at that time (inf = never).
    ddos: if enabled, every ``repick_s`` seconds a random minority set is
    attacked; their links gain ``attack_delay_ms`` each way."""
    crash_time_s: Optional[np.ndarray] = None
    ddos: bool = False
    ddos_attack_delay_ms: float = 800.0
    ddos_repick_s: float = 2.0
    ddos_seed: int = 7


def sim_ticks(cfg: SMRConfig) -> int:
    """Number of simulator ticks — static (known at trace time)."""
    return int(cfg.sim_seconds * 1000 / cfg.tick_ms)


def ddos_windows(cfg: SMRConfig, faults: FaultSchedule) -> int:
    """Rows needed in the attacked-minority table for this schedule."""
    if not faults.ddos:
        return 1
    return int(np.ceil(cfg.sim_seconds / faults.ddos_repick_s)) + 1


def build_env(cfg: SMRConfig, faults: FaultSchedule,
              n_windows: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    n = cfg.n_replicas
    # Channels cap a message's total delay at delay_horizon_ticks - 1
    # (channel.send clips); NIC backlog beyond the horizon is delivered at
    # the horizon by design, but the *static* link + attack delay exceeding
    # it is a misconfiguration that would silently distort every message.
    static_delay = (np.max(cfg.delays_ms())
                    + (faults.ddos_attack_delay_ms if faults.ddos else 0.0)
                    ) / cfg.tick_ms
    if static_delay >= cfg.delay_horizon_ticks:
        raise ValueError(
            f"link + DDoS delay ({static_delay:.0f} ticks) exceeds "
            f"delay_horizon_ticks={cfg.delay_horizon_ticks}; raise the "
            "horizon in SMRConfig")
    delays = jnp.asarray(cfg.delays_ms() / cfg.tick_ms)        # [n,n] ticks
    crash = (jnp.full((n,), jnp.inf) if faults.crash_time_s is None
             else jnp.asarray(faults.crash_time_s * 1000.0 / cfg.tick_ms))
    w = ddos_windows(cfg, faults)
    if n_windows is None:
        n_windows = w
    # pre-generate the attacked minority per repick window
    att = np.zeros((n_windows, n), np.bool_)
    if faults.ddos:
        rng = np.random.RandomState(faults.ddos_seed)
        f = (n - 1) // 2
        for k in range(w):
            att[k, rng.choice(n, size=f, replace=False)] = True
    return {
        "delays": delays,
        "crash_tick": crash,
        "attacked": jnp.asarray(att),
        "ddos_delay": jnp.float32(
            faults.ddos_attack_delay_ms / cfg.tick_ms if faults.ddos else 0.0),
        "repick_ticks": jnp.int32(max(1, int(
            faults.ddos_repick_s * 1000 / cfg.tick_ms))),
        "bytes_per_tick": jnp.float32(
            cfg.nic_gbps * 1e9 / 8.0 * cfg.tick_ms / 1000.0),
        "cpu_req_per_tick": jnp.float32(
            cfg.tick_ms * 1000.0 / cfg.cpu_us_per_request),
    }


def stack_envs(envs: Sequence[Dict[str, jnp.ndarray]]) -> Dict[str, jnp.ndarray]:
    """Stack envs leaf-wise into a batched env (leading axis = variant).
    All envs must come from the same cfg and a common ``n_windows``."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *envs)


def alive(env, t) -> jax.Array:
    """[n] bool — replica has not crashed."""
    return t < env["crash_tick"]


def link_delay(env, t) -> jax.Array:
    """[n, n] delay in ticks including DDoS extra delay on attacked nodes."""
    w = jnp.minimum(t // env["repick_ticks"], env["attacked"].shape[0] - 1)
    att = env["attacked"][w]                                   # [n]
    extra = (att[:, None] | att[None, :]) * env["ddos_delay"]
    return env["delays"] + extra


def egress_delay(busy: jax.Array, t: jax.Array, bytes_out: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """NIC serialization. busy: [n] abs tick when NIC frees; bytes_out: [n,n]
    bytes sent this tick (serialized in receiver order). Returns
    (new_busy [n], extra_delay_ticks [n,n])."""
    # cumulative serialization time per receiver j (order: j ascending)
    # NOTE: env['bytes_per_tick'] is folded in by the caller.
    cum = jnp.cumsum(bytes_out, axis=1)
    start = jnp.maximum(busy, t.astype(jnp.float32))[:, None]
    finish = start + cum
    new_busy = start[:, 0] + cum[:, -1]
    return new_busy, finish - t.astype(jnp.float32)
