"""The paper's own deployment configuration (§5.1–5.2).

Regions, RTT matrix, bandwidth, batch sizes and request sizes used by the
WAN simulator (core/netsim.py) and the figure benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple, Union

import numpy as np

# 9 AWS regions of §5.1 (first 5 used for figs 6-8; up to 9 for fig 9).
REGIONS: Tuple[str, ...] = (
    "virginia", "ireland", "mumbai", "saopaulo", "tokyo",
    "oregon", "ohio", "singapore", "sydney",
)

# Public inter-region RTT estimates (ms). Symmetric; diagonal ~0.5ms.
# Source: cloudping-style public measurements, rounded.
_RTT_MS = np.array([
    #  vir   ire   mum   sao   tok   ore   ohi   sin   syd
    [   1,   75,  185,  115,  160,   60,   12,  215,  200],  # virginia
    [  75,    1,  120,  175,  210,  130,   85,  175,  260],  # ireland
    [ 185,  120,    1,  300,  125,  215,  195,   60,  220],  # mumbai
    [ 115,  175,  300,    1,  255,  175,  125,  325,  310],  # saopaulo
    [ 160,  210,  125,  255,    1,   95,  145,   70,  105],  # tokyo
    [  60,  130,  215,  175,   95,    1,   50,  165,  140],  # oregon
    [  12,   85,  195,  125,  145,   50,    1,  200,  190],  # ohio
    [ 215,  175,   60,  325,   70,  165,  200,    1,   90],  # singapore
    [ 200,  260,  220,  310,  105,  140,  190,   90,    1],  # sydney
    # lint: allow(dtype-hygiene): host-side RTT reference table kept in
    # f64 for exact ms arithmetic; netsim.build_env downcasts to f32 at
    # the device boundary
], dtype=np.float64)


def one_way_delay_ms(n: int) -> np.ndarray:
    """One-way delay matrix for the first n regions."""
    assert 3 <= n <= 9
    return _RTT_MS[:n, :n] / 2.0


@dataclass(frozen=True)
class SMRConfig:
    """§5.2 workload + per-protocol batching constants."""
    n_replicas: int = 5
    request_bytes: int = 16            # 8B key + 8B value
    client_batch: int = 100            # client-side batch size
    max_batch_ms: float = 5.0          # replica max batch time
    nic_gbps: float = 10.0             # c4.4xlarge "up to 10 Gbps"
    # per-request replica CPU cost (µs) — calibrated so Multi-Paxos lands at
    # its measured ~40k tx/s plateau (DESIGN.md §8); shared by all protocols.
    cpu_us_per_request: float = 3.0
    # replica-side batch sizes (requests) per §5.2
    batch_epaxos: int = 1000
    batch_paxos: int = 5000
    batch_rabia: int = 300
    batch_sporades: int = 2000
    batch_mandator: int = 2000
    # §4 child processes: parallel stateless dissemination lanes per replica.
    # Each lane pipelines one outstanding Mandator-batch (chain completion
    # stays strictly in round order).
    mandator_lanes: int = 4
    # consensus metadata message size (bytes) — vector clock for mandator-*
    meta_bytes: int = 128
    epaxos_conflict_rate: float = 0.03
    view_timeout_ms: float = 300.0     # sporades/paxos view-change timeout
    sim_seconds: float = 10.0
    tick_ms: float = 1.0
    # Delayed-delivery horizon (ring-buffer slots) of the simulated channels:
    # a message's total delay (link + DDoS + NIC backlog) is capped at
    # horizon-1 ticks. Per-tick channel cost is linear in the horizon, so
    # the default "auto" sizes it exactly per sweep: static link delay +
    # the scenario's max extra delay + a NIC-backlog bound, next power of
    # two (netsim.resolve_horizon). Pass an int to pin it (2048 was the
    # seed-era fixed size: worst §5.5 attack + ~1s queueing headroom).
    delay_horizon_ticks: Union[int, str] = "auto"
    # Packed-channel-ring commit backend (repro.kernels.channel_ring):
    # "auto" = Pallas kernel on TPU, pure-jnp oracle elsewhere; also
    # "jnp"/"ref", "pallas", "pallas-interpret" (parity testing).
    channel_backend: str = "auto"
    # Flight recorder (repro.obs): "off" (default — the compiled program
    # is instruction-identical to an untraced build), "counters"
    # (per-kind event counts only), or "full" (event rings + per-batch
    # phase marks). Static: each level is its own compiled program.
    trace_level: str = "off"
    # Event-ring capacity per replica per layer at trace_level="full";
    # overflow keeps the newest events and counts the dropped oldest.
    trace_events: int = 512
    # Consensus health monitor (repro.obs.monitor): "off" (default — the
    # compiled program is instruction-identical to an unmonitored build,
    # exactly like trace_level), "gauges" (resource gauges only: ring
    # occupancy, dropped sends, inflight high-water, starvation), or
    # "full" (gauges + on-device safety/liveness invariant checks).
    # Static: each level is its own compiled program.
    monitor_level: str = "off"
    # Commit-stall watchdog grace window (ms). 0 = derive per sweep from
    # the view timeout and the scenario's delay tables (scenario-aware:
    # a DDoS that slows every link widens the window it is judged by).
    monitor_stall_grace_ms: float = 0.0

    def delays_ms(self) -> np.ndarray:
        return one_way_delay_ms(self.n_replicas)


PAPER_CLAIMS = {
    # headline numbers from the paper, used by EXPERIMENTS.md comparisons
    "mandator_sporades_tput": 300_000,   # tx/s, <900ms median, 5 replicas
    "mandator_paxos_tput": 300_000,
    "multipaxos_tput": 40_000,           # ~295ms median
    "epaxos_tput": 6_500,                # ~720ms median
    "rabia_tput": 500,                   # ~500ms median
    "ddos_mandator_sporades_tput": 400_000,  # under 5s median bound
    "ddos_mandator_paxos_tput": 250_000,
    "ddos_multipaxos_tput": 45_000,
    "ddos_epaxos_tput": 7_200,
    "scal_9_replicas_tput": 150_000,
}
