"""Compile accounting + persistent compilation cache (the "kill the
compile wall" layer): the fig 6/7/9 suites must lower to ONE canonical
program signature per protocol, a warm-cache second process must report
zero new XLA compiles with bitwise-identical results, and the
compile_cache enable/disable/ensure state machine must hold so the
pytest opt-out marker and the REPRO_COMPILE_CACHE=0 escape hatch work."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.configs.smr import SMRConfig
from repro.core import compile_cache, experiment
from repro.core.experiment import (
    CANONICAL_LANES,
    CANONICAL_MIN_WINDOWS,
    ProgramSignature,
    SweepSpec,
    _canon_pow2,
    _lower,
    run_sweep,
)
from repro.core.harness import run_sim
from repro.scenarios import Crash, Scenario

SRC = Path(__file__).resolve().parents[1] / "src"


# ------------------------------------------------ canonical signatures ----

def test_canon_pow2():
    assert _canon_pow2(1, 4) == 4
    assert _canon_pow2(4, 4) == 4
    assert _canon_pow2(5, 4) == 8
    assert _canon_pow2(6, 1) == 8
    assert _canon_pow2(8, 1) == 8
    assert _canon_pow2(9, 1) == 16


def _fig_specs(sim_s: float):
    """The fig 6 / 7 / 9(n=5) sweep shapes, as the benchmark builds them:
    a 4-rate grid, a 1-rate leader-crash grid, a 1-rate scalability
    point. Same cfg statics, wildly different native shapes."""
    return (
        SweepSpec(rates=(50_000, 150_000, 300_000, 450_000)),       # fig6
        SweepSpec(rates=(100_000,), scenarios=(Scenario("leader-crash", (
            Crash(start_s=sim_s / 2, targets=(0,)),)),)),            # fig7
        SweepSpec(rates=(60_000 * 5,)),                              # fig9
    )


def test_fig_suite_specs_lower_to_one_signature():
    """fig6 (4 rates, no scenario), fig7 (1 rate, crash), and fig9's n=5
    point (1 rate) produce the SAME canonical ProgramSignature — the
    lowering is protocol-independent, so this pins program sharing for
    every protocol at once without compiling anything."""
    cfg = SMRConfig(sim_seconds=2.0)  # the --quick suite length
    sigs = {_lower(cfg, spec, canonical=True)[-1]
            for spec in _fig_specs(cfg.sim_seconds)}
    assert len(sigs) == 1, f"fig 6/7/9 signatures diverged: {sigs}"
    (sig,) = sigs
    assert sig == ProgramSignature(
        n=5, ticks=2000, lanes=CANONICAL_LANES,
        scen_windows=CANONICAL_MIN_WINDOWS,
        wl_windows=CANONICAL_MIN_WINDOWS,
        horizon=256, trivial=True, closed=False)


def test_fig_shaped_sweeps_reuse_one_compiled_program():
    """End to end for mandator-sporades: running the three fig-suite
    shapes back to back traces exactly once — suites 2 and 3 reuse the
    compiled program (the same shapes at sim_seconds=1.0 to keep the
    tier-1 compile budget small; shape sharing is what is under test)."""
    cfg = SMRConfig(sim_seconds=1.0)
    experiment.reset_trace_counts()
    for spec in _fig_specs(cfg.sim_seconds):
        run_sweep("mandator-sporades", cfg, spec)
    # zero traces means an earlier test already compiled the shared
    # canonical program — the one-program claim is the signature count
    traced = experiment.trace_counts().get("mandator-sporades", 0)
    assert traced <= 1, "fig-shaped sweeps must share ONE compiled program"
    assert len(experiment.program_signatures()["mandator-sporades"]) == 1
    # and a single-point run_sim rides the same program too
    run_sim("mandator-sporades", cfg, 75_000)
    assert experiment.trace_counts().get("mandator-sporades", 0) == traced
    assert len(experiment.program_signatures()["mandator-sporades"]) == 1


def test_matrix_suite_signature_matches_fig8():
    """Satellite (warm-cache the robustness suite): the FULL scenario
    library — whose busiest schedule (gray-wan) needs up to 30 window
    rows at the 4s suite length — must lower to the SAME canonical
    signature as the fig8 paper-ddos sweep at both --quick (2s) and full
    (4s) lengths, so the robustness matrix reuses fig8's compiled program
    instead of missing the cache on a window-axis variant (the 32-row
    canonical floor is what absorbs the difference)."""
    from repro.scenarios import library as scenario_library
    for sim_s in (2.0, 4.0):
        cfg = SMRConfig(sim_seconds=sim_s)
        lib = scenario_library.scenarios(sim_s, cfg.n_replicas)
        fig8 = _lower(cfg, SweepSpec(rates=(300_000,),
                                     scenarios=(lib["paper-ddos"],)))[-1]
        robust = _lower(cfg, SweepSpec(rates=(50_000, 200_000),
                                       scenarios=tuple(lib.values())))[-1]
        assert fig8 == robust, (sim_s, fig8, robust)


def test_crowded_window_table_shares_canonical_program():
    """End to end: a 4-interval crash schedule lowers to >8 native window
    rows; the canonical floor must absorb it so the sweep reuses the
    baseline-shaped program with ZERO new traces (this is the in-process
    version of the robustness warm-cache satellite)."""
    cfg = SMRConfig(sim_seconds=0.5)
    experiment.reset_trace_counts()
    run_sweep("mandator", cfg, SweepSpec(rates=(20_000,)))
    base = experiment.trace_counts().get("mandator", 0)
    busy = Scenario("many-crashes", tuple(
        Crash(start_s=0.05 * i, end_s=0.05 * i + 0.02, targets=(i % 5,))
        for i in range(1, 5)))
    from repro import scenarios as sc
    tab = sc.lower(cfg, busy)
    assert tab["alive"].shape[0] > 8, "scenario must exceed the old floor"
    run_sweep("mandator", cfg, SweepSpec(rates=(20_000,), scenarios=(busy,)))
    assert experiment.trace_counts().get("mandator", 0) == base, \
        "crowded window table must reuse the canonical program"
    assert len(experiment.program_signatures()["mandator"]) == 1


def test_native_lowering_keeps_exact_shapes():
    cfg = SMRConfig(sim_seconds=1.0)
    spec = SweepSpec(rates=(10_000, 20_000))
    sig = _lower(cfg, spec, canonical=False)[-1]
    assert (sig.lanes, sig.scen_windows, sig.wl_windows) == (2, 1, 1)


def test_compile_report_shape():
    experiment.reset_trace_counts()
    rep = experiment.compile_report()
    assert set(rep) == {"traces", "programs", "signatures", "cache"}
    for k in compile_cache.STAT_KEYS:
        assert k in rep["cache"]


# ------------------------------------------- persistent cache plumbing ----

@pytest.mark.no_persistent_cache
def test_enable_disable_and_counters(tmp_path):
    """A fresh jit compiles into the pinned dir (miss); re-compiling the
    same program after clearing the in-memory jit caches loads it back
    (hit) instead of recompiling."""
    import jax
    import jax.numpy as jnp

    compile_cache.enable(tmp_path)
    try:
        assert compile_cache.enabled()
        assert compile_cache.cache_dir() == tmp_path

        def fresh(x):
            return jnp.sin(x) * 3.0 + jnp.cos(x)

        before = compile_cache.stats()
        jax.jit(fresh)(jnp.arange(7.0)).block_until_ready()
        d = compile_cache.delta(before)
        assert d["persistent_cache_misses"] >= 1
        assert any(tmp_path.iterdir()), "no executable written to cache dir"

        jax.clear_caches()
        before = compile_cache.stats()
        jax.jit(fresh)(jnp.arange(7.0)).block_until_ready()
        d = compile_cache.delta(before)
        assert d["persistent_cache_hits"] >= 1
        assert d["persistent_cache_misses"] == 0
    finally:
        compile_cache.disable()


@pytest.mark.no_persistent_cache
def test_ensure_respects_explicit_disable(tmp_path):
    compile_cache.disable()
    assert compile_cache.ensure() is None, \
        "ensure() must not undo an explicit disable()"
    compile_cache.enable(tmp_path)
    assert compile_cache.ensure() == tmp_path
    compile_cache.disable()
    assert not compile_cache.enabled()
    assert compile_cache.cache_dir() is None


def test_ensure_respects_env_opt_out(monkeypatch):
    monkeypatch.setenv(compile_cache.DISABLE_ENV, "0")
    was = compile_cache.enabled()
    # must not flip the cache on when the env says no (and must not
    # disable an already-enabled cache either)
    assert (compile_cache.ensure() is not None) == was


# --------------------------------------- warm process compiles nothing ----

_SWEEP_SCRIPT = """\
import json, sys
from repro.core import compile_cache, experiment
from repro.configs.smr import SMRConfig
from repro.core.experiment import SweepSpec, run_sweep

compile_cache.enable(sys.argv[1])
cfg = SMRConfig(sim_seconds=0.4)
res = run_sweep("mandator", cfg, SweepSpec(rates=(20_000, 60_000)))
rep = experiment.compile_report()
out = {
    "misses": rep["cache"]["persistent_cache_misses"],
    "hits": rep["cache"]["persistent_cache_hits"],
    "backend_compile_s": rep["cache"]["backend_compile_s"],
    "traces": rep["traces"],
    "results": [{
        "throughput": repr(r["throughput"]),
        "median_ms": repr(r["median_ms"]),
        "p99_ms": repr(r["p99_ms"]),
        "committed": repr(r["committed"]),
        "timeline": [repr(float(x)) for x in r["timeline"]],
    } for r in res],
}
print(json.dumps(out, sort_keys=True))
"""


def _run_sweep_subprocess(cache_dir: Path) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    # scope the subprocess strictly to the pinned dir
    env.pop("JAX_COMPILATION_CACHE_DIR", None)
    out = subprocess.run(
        [sys.executable, "-c", _SWEEP_SCRIPT, str(cache_dir)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr}"
    return json.loads(out.stdout)


def test_warm_cache_process_zero_new_compiles_bitwise_identical(tmp_path):
    """The tentpole claim, end to end: with the cache dir pinned, a second
    process running the same sweep reports ZERO persistent-cache misses
    (every XLA executable is loaded, none compiled) and produces
    bitwise-identical metrics (compared via repr round-trip, which is
    exact for floats)."""
    cold = _run_sweep_subprocess(tmp_path)
    assert cold["misses"] > 0, "cold run must populate the cache"
    assert cold["traces"] == {"mandator": 1}

    warm = _run_sweep_subprocess(tmp_path)
    assert warm["misses"] == 0, \
        f"warm run recompiled {warm['misses']} programs"
    assert warm["hits"] >= cold["misses"]
    assert warm["traces"] == {"mandator": 1}, \
        "tracing still happens per process (only XLA compile is cached)"
    assert warm["results"] == cold["results"], \
        "warm-cache results must be bitwise identical"


def test_saved_time_counter_clamps_negative_events():
    """jax reports compile_time_saved per hit as (estimated compile) -
    (retrieval cost), which goes negative for cheap programs — raw
    accumulation made whole suites report negative savings. The listener
    clamps per event: negatives are dropped, positives accumulate."""
    before = compile_cache.stats()["compile_saved_s"]
    compile_cache._on_duration(compile_cache._DUR_SAVED,
                               duration_secs=-0.5)
    assert compile_cache.stats()["compile_saved_s"] == pytest.approx(before)
    compile_cache._on_duration(compile_cache._DUR_SAVED, duration_secs=0.25)
    compile_cache._on_duration(compile_cache._DUR_SAVED,
                               duration_secs=-1.25)
    assert compile_cache.stats()["compile_saved_s"] == pytest.approx(
        before + 0.25)
