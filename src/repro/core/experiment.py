"""Batched experiment engine: an entire workload × scenario × rate × seed
sweep grid as ONE compiled JAX program per protocol.

The paper's headline results (Figs. 6–9) are sweeps over arrival rate,
protocol, and network scenario — and, beyond the paper, over *traffic
shape* (``repro.workloads``). Instead of re-tracing the tick-level
``jax.lax.scan`` for every grid point, ``run_sweep`` lowers a ``SweepSpec``
to a single ``jax.vmap``-over-scan dispatch:

  1. the channel delay horizon is resolved ONCE for the whole sweep
     (``netsim.resolve_horizon`` over every scenario in the grid) so all
     points share one ring shape — the packed channel rings are then
     exactly as large as the sweep's true delay bound;
  2. every scenario variant becomes an array-native env
     (``netsim.build_env`` with a common window-table pad), stacked
     leaf-wise — and every workload variant becomes a windowed rate table
     (``workloads.lower``, same pad-and-stack trick);
  3. the cartesian grid is flattened to B points, each an
     (env, workload-table, rate, seed) tuple gathered from the stacks;
  4. ``harness.sim_point`` — scan *plus* on-device metric extraction — is
     vmapped over the B axis and jitted once per
     (protocol, cfg, workload-mode, B) shape.

The analytic baselines (epaxos / rabia) have no tick loop; they are looped
on the host behind the same API (time-varying rates come from the same
compiled tables via ``workloads.analytic``) so callers can sweep any
protocol.

``trace_counts()`` exposes how many times each protocol's program was
traced — the equivalence tests (tests/test_experiment.py,
tests/test_workloads.py) pin a whole grid to one trace — and
``timing_stats()`` the compile-vs-run wall-clock split plus the resolved
ring horizon, which benchmarks/run.py persists to BENCH_core.json.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import workloads as wlc
from repro.configs.smr import SMRConfig
from repro.core import harness, netsim

ANALYTIC_PROTOCOLS = ("epaxos", "rabia")

_TRACE_COUNTS: Dict[str, int] = {}
_TIMING: Dict[str, Dict[str, float]] = {}


def trace_counts() -> Dict[str, int]:
    """jit traces of the sweep program per protocol since the last reset."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


def timing_stats() -> Dict[str, Dict[str, float]]:
    """Per-protocol wall-clock of the sweep dispatches since the last
    reset: ``compile_s`` (calls that traced — compile + first run),
    ``run_s`` (cache-hit calls), ``dispatches``, and ``horizon`` (the
    resolved ring size of the latest sweep)."""
    return {k: dict(v) for k, v in _TIMING.items()}


def reset_timing_stats() -> None:
    _TIMING.clear()


@dataclass(frozen=True)
class SweepSpec:
    """A sweep grid: cartesian product of rates (tx/s), PRNG seeds,
    network-scenario variants, and traffic-shape variants. Each entry of
    ``scenarios`` is a ``repro.scenarios.Scenario`` (None = fault-free
    baseline); each entry of ``workloads`` is a ``repro.workloads.Workload``
    (None = the §5.2 open-loop Poisson baseline). ``points()`` yields the
    flattened grid in rate-major order as (rate, seed, scenario_index,
    workload_index) — the same order ``run_sweep`` returns results in."""
    rates: Tuple[float, ...]
    seeds: Tuple[int, ...] = (0,)
    scenarios: Tuple = (None,)
    workloads: Tuple = (None,)

    def points(self) -> Iterator[Tuple[float, int, int, int]]:
        for rate, seed, fi, wi in itertools.product(
                self.rates, self.seeds, range(len(self.scenarios)),
                range(len(self.workloads))):
            yield float(rate), int(seed), fi, wi

    @property
    def size(self) -> int:
        return (len(self.rates) * len(self.seeds) * len(self.scenarios)
                * len(self.workloads))


@partial(jax.jit, static_argnames=("protocol", "cfg", "mode"))
def _sweep_compiled(protocol: str, cfg: SMRConfig, mode: wlc.WorkloadMode,
                    env_b: Dict, wl_b: Dict, rate_b: jax.Array,
                    seed_b: jax.Array) -> Dict:
    # body executes only while tracing, so this counts compilations
    _TRACE_COUNTS[protocol] = _TRACE_COUNTS.get(protocol, 0) + 1
    return jax.vmap(lambda env, wlt, rate, seed: harness.sim_point(
        protocol, cfg, env, rate, seed, wlt, mode))(
        env_b, wl_b, rate_b, seed_b)


def _lower(cfg: SMRConfig, spec: SweepSpec):
    """Flatten the grid to stacked per-point inputs (env leaves, workload
    table leaves, rate, seed) plus the static workload mode and the
    horizon-resolved cfg (one ring shape for the whole grid)."""
    from repro import scenarios as sc
    pts = list(spec.points())
    # lower every scenario ONCE: the tables feed both the sweep-wide
    # horizon resolution and the padded env stack. build_env gets the
    # ORIGINAL cfg (envs don't embed the horizon), so its static-delay
    # validation sees the user's auto-vs-pinned intent exactly as a
    # direct build_env call would; only the compiled program takes the
    # sweep-wide resolved horizon.
    stabs = [sc.lower(cfg, sc.as_scenario(f)) for f in spec.scenarios]
    n_windows = max(t["alive"].shape[0] for t in stabs)
    stack = netsim.stack_envs(
        [netsim.build_env(cfg, f, n_windows, tab=t)
         for f, t in zip(spec.scenarios, stabs)])
    cfg = netsim.resolve_horizon(cfg, tabs=stabs)
    fidx = np.array([fi for _, _, fi, _ in pts], np.int32)
    env_b = jax.tree.map(lambda x: x[fidx], stack)
    wl_pad = max(wlc.compile.n_windows(cfg, w) for w in spec.workloads)
    tabs = [wlc.lower(cfg, w, pad_windows=wl_pad) for w in spec.workloads]
    mode = wlc.mode_of(tabs)
    widx = np.array([wi for _, _, _, wi in pts], np.int32)
    # win_start is host-side metadata (ragged across workloads); only the
    # fixed-shape device tables ride into the compiled program
    dev = [{k: v for k, v in t.items() if k != "win_start"} for t in tabs]
    wl_b = jax.tree.map(
        lambda *xs: jnp.asarray(np.stack(xs))[widx], *dev)
    # per-replica Poisson rate per tick, computed host-side in float64 so a
    # batched grid and a single run_sim see bit-identical inputs
    rate_b = jnp.asarray(
        np.array([r for r, _, _, _ in pts], np.float64)
        * cfg.tick_ms / 1000.0 / cfg.n_replicas, jnp.float32)
    seed_b = jnp.asarray([s for _, s, _, _ in pts], jnp.int32)
    return pts, cfg, mode, env_b, wl_b, rate_b, seed_b


def run_sweep(protocol: str, cfg: SMRConfig, spec: SweepSpec) -> List[Dict]:
    """Run the whole grid; returns one result dict per point, in
    ``spec.points()`` order. Scan protocols execute as a single vmapped
    device dispatch; analytic baselines loop on the host."""
    wl_names = [wlc.as_workload(w).name for w in spec.workloads]
    if protocol in ANALYTIC_PROTOCOLS:
        if protocol == "epaxos":
            from repro.core.epaxos import run_epaxos_model as model
        else:
            from repro.core.rabia import run_rabia_model as model
        out = []
        for rate, seed, fi, wi in spec.points():
            r = model(cfg, rate, spec.scenarios[fi],
                      workload=spec.workloads[wi])
            r["seed"] = seed
            r["workload"] = wl_names[wi]
            out.append(r)
        return out
    if protocol not in harness.SCAN_PROTOCOLS:
        raise ValueError(protocol)

    pts, cfg, mode, env_b, wl_b, rate_b, seed_b = _lower(cfg, spec)
    traces_before = _TRACE_COUNTS.get(protocol, 0)
    t0 = time.perf_counter()
    out = jax.tree.map(np.asarray, _sweep_compiled(
        protocol, cfg, mode, env_b, wl_b, rate_b, seed_b))
    dt = time.perf_counter() - t0
    stats = _TIMING.setdefault(protocol, {
        "compile_s": 0.0, "run_s": 0.0, "dispatches": 0, "horizon": 0})
    bucket = ("compile_s" if _TRACE_COUNTS.get(protocol, 0) > traces_before
              else "run_s")
    stats[bucket] += dt
    stats["dispatches"] += 1
    stats["horizon"] = int(cfg.delay_horizon_ticks)
    results: List[Dict] = []
    for i, (rate, seed, fi, wi) in enumerate(pts):
        r: Dict = {"protocol": protocol, "rate": rate, "seed": seed,
                   "workload": wl_names[wi],
                   "throughput": float(out["throughput"][i]),
                   "median_ms": float(out["median_ms"][i]),
                   "p99_ms": float(out["p99_ms"][i]),
                   "committed": float(out["committed"][i]),
                   "timeline": out["timeline"][i],
                   "origin_median_ms": out["origin_median_ms"][i],
                   "origin_p99_ms": out["origin_p99_ms"][i],
                   "origin_timeline": out["origin_timeline"][i],
                   "origin_lat_ms_timeline": out["origin_lat_ms_timeline"][i]}
        if protocol == "mandator-sporades":
            r["async_frac"] = float(out["async_frac"][i])
            r["views"] = int(out["views"][i])
            r["cvc_all"] = out["cvc_all"][i]
            r["commit_key"] = out["commit_key"][i]
        if "inflight_max" in out:
            r["inflight_max"] = out["inflight_max"][i]
        results.append(r)
    return results
