"""On-device protocol flight recorder: fixed-shape, vmap-safe event rings.

Every scan protocol carries one trace state per layer (mandator /
sporades / paxos) inside the ``jax.lax.scan`` carry, mirroring the
``channel.RingSpec`` idiom: the event taxonomy is declared once as a
``TraceSpec`` (declaration order = kind id), the ring is a fixed-shape
int32 buffer ``[n, cap, 4]`` of (kind, tick, a, b) rows, and recording
is a masked scatter — so a whole sweep grid vmaps the recorder exactly
like it vmaps the channel rings.

Gating is *static*: ``SMRConfig.trace_level`` is a frozen-dataclass field
and cfg is a jit static argument, so at ``TraceLevel.OFF`` (the default)
``init_trace`` returns None and every ``record`` call is a Python no-op —
the traced computation is instruction-identical to an untraced build
(tests/test_obs.py pins the outputs bitwise). ``COUNTERS`` keeps only the
per-kind event counters; ``FULL`` adds the event ring.

Overflow semantics: the ring keeps the **newest** ``cap`` events. The
write slot is ``ptr % cap``, which is exactly the oldest live entry once
``ptr >= cap`` — overwriting it drops the oldest event and bumps a
saturating ``dropped`` counter (never corrupts, never wraps negative).
``obs/decode.py`` unwraps the ring back into arrival order.

Payloads are int32 throughout: sporades rank keys reach
``MAX_VIEWS * RS = 2**26``, past float32's exact-integer range.
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class TraceLevel:
    """Static trace gate. OFF compiles the recorder out entirely;
    COUNTERS keeps per-kind event counts; FULL adds the event ring."""
    OFF = "off"
    COUNTERS = "counters"
    FULL = "full"
    ORDER = (OFF, COUNTERS, FULL)

    @staticmethod
    def check(level: str) -> str:
        if level not in TraceLevel.ORDER:
            raise ValueError(
                f"trace_level {level!r}; expected one of {TraceLevel.ORDER}")
        return level


TRACE_ENV = "REPRO_TRACE"  # benchmarks read the level from the environment


def level_from_env(default: str = TraceLevel.OFF) -> str:
    """Trace level from ``REPRO_TRACE`` (off/counters/full); benchmarks use
    this so the default artifact path stays byte-identical to an untraced
    build while ``REPRO_TRACE=full`` turns the same suites into trace
    producers."""
    return TraceLevel.check(os.environ.get(TRACE_ENV, default))


class TraceSpec:
    """The event taxonomy: a tuple of (name, (arg_a, arg_b)) pairs.
    Declaration order is the on-device kind id, exactly like
    ``channel.RingSpec`` derives field offsets from declaration order."""

    def __init__(self, *events: Tuple[str, Tuple[str, str]]):
        self.events = tuple(events)
        self.names = tuple(name for name, _ in events)
        self._kind = {name: i for i, (name, _) in enumerate(events)}
        if len(self._kind) != len(events):
            raise ValueError("duplicate event names")

    @property
    def n_kinds(self) -> int:
        return len(self.events)

    def kind(self, name: str) -> int:
        return self._kind[name]

    def args_of(self, name_or_kind) -> Tuple[str, str]:
        if isinstance(name_or_kind, str):
            return self.events[self._kind[name_or_kind]][1]
        return self.events[int(name_or_kind)][1]


# One shared taxonomy for every protocol layer; a layer records the subset
# that exists in its state machine (e.g. multipaxos never mode-switches).
DEFAULT_SPEC = TraceSpec(
    ("view_change", ("view", "round")),       # consensus view/round advance
    ("mode_switch", ("is_async", "view")),    # sporades sync<->async
    ("leader_change", ("leader", "view")),
    ("batch_create", ("round", "count")),     # round/slot formed
    ("batch_disseminate", ("round", "egress_ticks")),
    ("batch_ack", ("round", "quorum")),       # quorum of votes reached
    ("batch_stable", ("round", "completed")),  # completion (stable) point
    ("commit", ("key", "total")),             # ordered/committed
    ("crash", ("view", "round")),             # alive -> down transition
    ("recover", ("view", "round")),           # down -> alive transition
    ("drop", ("links", "view")),              # sends cut by partition/drop
)

# Event-ring record fields, in buffer order (buf[..., i]).
FIELDS = ("kind", "tick", "a", "b")

# Latency-breakdown phases (harness.sim_point), in output order: a
# committed batch's end-to-end latency = queue (client arrival -> batch
# create at the origin) + dissemination (create -> n-f votes / stable) +
# consensus (stable -> ordered anywhere) + delivery (ordered -> the
# origin itself observes the commit).
PHASES = ("queue", "dissemination", "consensus", "delivery")

_SAT = np.int32(2**31 - 1)  # saturation bound of the dropped counter


def init_trace(spec: TraceSpec, level: str, n: int,
               cap: int) -> Optional[Dict[str, jax.Array]]:
    """Per-layer trace state, or None at TraceLevel.OFF (so carrying it in
    protocol state dicts is structurally free when tracing is off)."""
    TraceLevel.check(level)
    if level == TraceLevel.OFF:
        return None
    ts = {
        "counts": jnp.zeros((n, spec.n_kinds), jnp.int32),
        # crash/recover edge detection (netsim.alive is the level signal)
        "prev_alive": jnp.ones((n,), jnp.bool_),
    }
    if level == TraceLevel.FULL:
        if cap < 1:
            raise ValueError(f"trace_events must be >= 1, got {cap}")
        ts["buf"] = jnp.zeros((n, cap, len(FIELDS)), jnp.int32)
        ts["ptr"] = jnp.zeros((n,), jnp.int32)
        ts["dropped"] = jnp.zeros((n,), jnp.int32)
    return ts


def _bcast_i32(x, n: int) -> jax.Array:
    return jnp.broadcast_to(jnp.asarray(x).astype(jnp.int32), (n,))


def record(spec: TraceSpec, ts: Optional[Dict], name: str, mask: jax.Array,
           t: jax.Array, a=0, b=0) -> Optional[Dict]:
    """Record event ``name`` for every replica where ``mask`` is set, with
    int payloads ``a``/``b`` (scalars or [n] arrays; floats are cast).
    None trace state (level off) passes straight through, so call sites
    need no level branching of their own."""
    if ts is None:
        return None
    kind = spec.kind(name)
    n = ts["counts"].shape[0]
    mask = jnp.asarray(mask, jnp.bool_)
    inc = mask.astype(jnp.int32)
    ts = dict(ts)
    ts["counts"] = ts["counts"].at[:, kind].add(inc)
    if "buf" in ts:
        cap = ts["buf"].shape[1]
        rows = jnp.arange(n)
        slot = ts["ptr"] % cap  # == the oldest live entry once ptr >= cap
        rec = jnp.stack([jnp.full((n,), kind, jnp.int32), _bcast_i32(t, n),
                         _bcast_i32(a, n), _bcast_i32(b, n)], axis=-1)
        cur = ts["buf"][rows, slot]
        ts["buf"] = ts["buf"].at[rows, slot].set(
            jnp.where(mask[:, None], rec, cur))
        drop_inc = inc * (ts["ptr"] >= cap)
        ts["ptr"] = ts["ptr"] + inc
        ts["dropped"] = jnp.where(ts["dropped"] >= _SAT, _SAT,
                                  ts["dropped"] + drop_inc)
    return ts


def record_env(spec: TraceSpec, ts: Optional[Dict], alive: jax.Array,
               t: jax.Array, a=0, b=0,
               dropped_links: Optional[jax.Array] = None) -> Optional[Dict]:
    """Environment-driven events shared by every protocol layer:
    crash/recover edges of ``netsim.alive`` and sends cut by link drops
    this tick (``dropped_links``: per-sender count)."""
    if ts is None:
        return None
    alive = jnp.asarray(alive, jnp.bool_)
    prev = ts["prev_alive"]
    ts = record(spec, ts, "crash", prev & ~alive, t, a=a, b=b)
    ts = record(spec, ts, "recover", ~prev & alive, t, a=a, b=b)
    if dropped_links is not None:
        ts = record(spec, ts, "drop", dropped_links > 0, t,
                    a=dropped_links, b=a)
    ts = dict(ts)
    ts["prev_alive"] = alive
    return ts


class HostTrace:
    """Host-side sibling of the device ring, for the pure-numpy paths
    (the analytic rabia slot loop, the runtime/*_rt.py control-plane
    drivers): same event taxonomy, plain-list storage, no capacity
    games. ``events`` is already in arrival order."""

    def __init__(self, spec: TraceSpec = DEFAULT_SPEC):
        self.spec = spec
        self.events: list = []

    def record(self, name: str, tick, who: int = 0, **args) -> None:
        self.spec.kind(name)  # unknown names fail fast, like the ring
        self.events.append({"name": name, "tick": float(tick),
                            "who": int(who),
                            "args": {k: (float(v) if isinstance(v, float)
                                         else int(v))
                                     for k, v in args.items()}})

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e["name"]] = out.get(e["name"], 0) + 1
        return out


def public_view(ts: Optional[Dict]) -> Optional[Dict]:
    """The trace leaves worth surfacing out of the scan (everything but
    the edge-detector scratch)."""
    if ts is None:
        return None
    return {k: v for k, v in ts.items() if k != "prev_alive"}
