"""Mesh-sharded sweep engine (ISSUE 10): the quantile sketch must match
``harness._weighted_quantile`` (exactly on small inputs, within a pinned
rank tolerance in general), the sharded dispatch path must be BITWISE
identical to the legacy per-point loop on a single-device mesh and on an
8-way forced-host-device mesh (subprocess: XLA_FLAGS must be set before
jax initializes), obs/monitor outputs must ride inside the sharded
program, and the benchmarks/run.py merge layer must clamp negative
cache_saved_s from stale entries."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.smr import SMRConfig
from repro.core import experiment
from repro.core.experiment import SweepSpec, dispatch_sweep, run_sweep
from repro.core.harness import REDUCED_DROPS, _weighted_quantile
from repro.distributed import mesh as dmesh
from repro.distributed import sketch
from repro.scenarios import library as scenario_library
from repro.workloads import library as workload_library

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"

SCALARS = ("throughput", "median_ms", "p99_ms", "committed")


def _same(a, b) -> bool:
    return a == b or (isinstance(a, float) and np.isnan(a) and np.isnan(b))


# ------------------------------------------------------- quantile sketch ----

def test_sketch_exact_on_small_inputs():
    """<= SKETCH_BINS equally-weighted distinct values: every value lands
    in its own rank bucket, so decode == the exact weighted quantile."""
    v = jnp.linspace(3.0, 99.0, 60)
    w = jnp.ones(60)
    sk = sketch.build(v, w)
    for q in (0.01, 0.1, 0.5, 0.9, 0.99):
        exact = float(_weighted_quantile(v, w, q))
        assert float(sketch.quantile(sk, q)) == exact
        # host decode must match the device decode bit for bit
        assert sketch.quantile_np(np.asarray(sk["v"]),
                                  np.asarray(sk["w"]), q) == exact


def test_sketch_rank_tolerance_on_large_weighted_sample():
    """General case: the decoded quantile's true rank must sit within
    ~2.5 bucket widths of the requested rank (uniform rank buckets +
    weighted-mean centers)."""
    rng = np.random.default_rng(7)
    v = rng.gamma(2.0, 10.0, size=5000).astype(np.float32)
    w = rng.uniform(0.5, 2.0, size=5000).astype(np.float32)
    sk = sketch.build(jnp.asarray(v), jnp.asarray(w))
    order = np.argsort(v)
    cv, cdf = v[order], np.cumsum(w[order]) / np.sum(w)
    for q in (0.1, 0.5, 0.9, 0.99):
        got = float(sketch.quantile(sk, q))
        rank = cdf[np.searchsorted(cv, got, side="right") - 1]
        assert abs(rank - q) <= 2.5 / sketch.SKETCH_BINS, (q, rank)


def test_sketch_merge_matches_whole():
    a = sketch.build(jnp.arange(1.0, 33.0), jnp.ones(32))
    b = sketch.build(jnp.arange(33.0, 65.0), jnp.ones(32))
    m = sketch.merge(a, b)
    allv, allw = jnp.arange(1.0, 65.0), jnp.ones(64)
    for q in (0.25, 0.5, 0.75, 0.99):
        assert float(sketch.quantile(m, q)) == \
            float(_weighted_quantile(allv, allw, q))


def test_sketch_edge_cases():
    # all-zero weight -> NaN, like _weighted_quantile's empty window
    sk0 = sketch.build(jnp.array([1.0, 2.0]), jnp.zeros(2))
    assert np.isnan(float(sketch.quantile(sk0, 0.5)))
    assert np.isnan(sketch.quantile_np(np.asarray(sk0["v"]),
                                       np.asarray(sk0["w"]), 0.5))
    # inf values at zero weight (uncommitted batches) are inert
    ski = sketch.build(jnp.array([5.0, np.inf, np.nan]),
                       jnp.array([1.0, 0.0, 0.0]))
    assert float(sketch.quantile(ski, 0.9)) == 5.0
    for k in ("v", "w"):
        assert ski[k].dtype == jnp.float32
        assert ski[k].shape == (sketch.SKETCH_BINS,)


# ----------------------------------------------------------- mesh helpers ----

def test_grid_mesh_helpers():
    m = dmesh.grid_mesh()
    assert m.axis_names == (dmesh.GRID_AXIS,)
    assert dmesh.as_grid_mesh(None) is None
    assert dmesh.as_grid_mesh(m) is m
    assert dmesh.as_grid_mesh(1).devices.size == 1
    with pytest.raises(ValueError):
        dmesh.grid_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError):
        dmesh.as_grid_mesh(jax.sharding.Mesh(
            np.array(jax.devices()[:1]), ("other",)))
    counts = dmesh.device_counts()
    assert counts[0] == 1 and counts[-1] == len(jax.devices())


# ------------------------------------------- sharded == legacy (1 device) ----

def test_sharded_single_device_bitwise_equals_legacy():
    """The pinned invariant: a 1-device grid mesh produces bitwise the
    same scalar metrics as the legacy per-point dispatch loop, for both
    protocol families, with the heavy per-batch arrays replaced by the
    fixed-size sketch."""
    cfg = SMRConfig(sim_seconds=0.4)
    crash = scenario_library.get("leader-crash-recover", cfg.sim_seconds)
    spec = SweepSpec(rates=(50_000, 150_000), seeds=(0, 1),
                     scenarios=(None, crash))
    for proto in ("mandator-sporades", "multipaxos"):
        legacy = run_sweep(proto, cfg, spec)
        shard = run_sweep(proto, cfg, spec, mesh=1)
        assert len(legacy) == len(shard) == spec.size
        for a, b in zip(legacy, shard):
            for k in SCALARS:
                assert _same(a[k], b[k]), (proto, k, a[k], b[k])
            if proto == "mandator-sporades":
                assert _same(a["async_frac"], b["async_frac"])
                assert a["views"] == b["views"]
            for k in REDUCED_DROPS:
                assert k not in b, k
            assert b["sketch"]["v"].shape == (sketch.SKETCH_BINS,)
            # the on-device sketch decodes to the neighborhood of the
            # exact on-device quantiles (same window, same weights)
            if np.isfinite(a["median_ms"]) and a["committed"] > 0:
                med = sketch.quantile_np(b["sketch"]["v"],
                                         b["sketch"]["w"], 0.5)
                assert med == pytest.approx(a["median_ms"], rel=0.1)


def test_sharded_closed_loop_and_monitor_ride_along():
    """Closed-loop feedback (inflight_max) and the health monitor's gauge
    outputs must survive the reduced/sharded path unchanged."""
    cfg = SMRConfig(sim_seconds=0.4, monitor_level="gauges")
    wl = workload_library.get("closed-loop", cfg.sim_seconds)
    spec = SweepSpec(rates=(50_000,), workloads=(wl,))
    legacy = run_sweep("mandator", cfg, spec)
    shard = run_sweep("mandator", cfg, spec, mesh=1)
    for a, b in zip(legacy, shard):
        for k in SCALARS:
            assert _same(a[k], b[k]), (k, a[k], b[k])
        assert np.array_equal(np.asarray(a["inflight_max"]),
                              np.asarray(b["inflight_max"]))
        assert "mon" in b
        ja, jb = jax.tree.flatten(a["mon"])[0], jax.tree.flatten(b["mon"])[0]
        for xa, xb in zip(ja, jb):
            assert np.array_equal(np.asarray(xa), np.asarray(xb),
                                  equal_nan=True)


def test_sharded_registers_canonical_signature_and_traces():
    """The sharded path must register the SAME canonical ProgramSignature
    as the legacy path (cache keys unchanged) plus its (sig, devices)
    pair in shard_signatures(), and re-dispatching must not re-trace."""
    cfg = SMRConfig(sim_seconds=0.4)
    spec = SweepSpec(rates=(20_000, 60_000))
    experiment.reset_trace_counts()
    run_sweep("mandator", cfg, spec)
    legacy_sigs = experiment.program_signatures()["mandator"]
    run_sweep("mandator", cfg, spec, mesh=1)
    assert experiment.program_signatures()["mandator"] == legacy_sigs
    shard_sigs = experiment.shard_signatures()["mandator"]
    assert shard_sigs == ((legacy_sigs[0], 1),)
    traces = experiment.trace_counts()["mandator"]
    run_sweep("mandator", cfg, spec, mesh=1)  # memoized program: no trace
    assert experiment.trace_counts()["mandator"] == traces


# --------------------------------- 8-way host-device mesh parity (subproc) ----

_PARITY_SCRIPT = """\
import json
import jax
assert len(jax.devices()) == 8, jax.devices()
from repro.core import compile_cache
from repro.configs.smr import SMRConfig
from repro.core.experiment import SweepSpec, run_sweep
from repro.distributed import mesh as dmesh

compile_cache.enable()
cfg = SMRConfig(sim_seconds=0.25)
# 10 points over 8 devices: exercises the pad-to-multiple-of-D path
spec = SweepSpec(rates=(30e3, 90e3, 150e3, 210e3, 270e3), seeds=(0, 1))
out = {}
for proto in ("mandator-sporades", "multipaxos"):
    legacy = run_sweep(proto, cfg, spec)
    d1 = run_sweep(proto, cfg, spec, mesh=1)
    d8 = run_sweep(proto, cfg, spec, mesh=dmesh.grid_mesh(8))
    rows = []
    for a, b, c in zip(legacy, d1, d8):
        row = {}
        for k in ("throughput", "median_ms", "p99_ms", "committed"):
            row[k] = [repr(a[k]), repr(b[k]), repr(c[k])]
        row["sketch_v"] = [repr(b["sketch"]["v"].tolist()),
                           repr(c["sketch"]["v"].tolist())]
        row["sketch_w"] = [repr(b["sketch"]["w"].tolist()),
                           repr(c["sketch"]["w"].tolist())]
        rows.append(row)
    out[proto] = rows
print(json.dumps(out))
"""


@pytest.mark.slow
def test_eight_way_host_device_mesh_bitwise_parity():
    """Force 8 host devices in a subprocess (XLA_FLAGS must precede jax
    backend init) and pin: legacy == 1-device mesh == 8-way mesh, bitwise,
    for both protocol families, on a grid that needs padding (10 over 8).
    The device-side sketches must match across meshes too."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    out = subprocess.run([sys.executable, "-c", _PARITY_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    res = json.loads(out.stdout)
    for proto, rows in res.items():
        assert len(rows) == 10
        for i, row in enumerate(rows):
            for k in ("throughput", "median_ms", "p99_ms", "committed"):
                la, d1, d8 = row[k]
                assert la == d1 == d8, (proto, i, k, row[k])
            assert row["sketch_v"][0] == row["sketch_v"][1], (proto, i)
            assert row["sketch_w"][0] == row["sketch_w"][1], (proto, i)


# ------------------------------------------------ bench merge-layer clamp ----

def test_bench_merge_layer_clamps_negative_cache_saved():
    """Satellite: BENCH_core.json entries written by older revisions can
    carry negative cache_saved_s; the benchmarks/run.py merge layer must
    clamp BOTH the stale previous entries and this run's entries."""
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks.run import merge_suites, sanitize_entry
    finally:
        sys.path.pop(0)
    stale = {"suites": {
        "channel": {"wall_s": 5.0, "cache_saved_s": -0.126},
        "kernels": {"wall_s": 4.0, "cache_saved_s": -0.025},
        "fig6": {"wall_s": 7.0, "cache_saved_s": 4.424},
        "weird": {"wall_s": 1.0, "cache_saved_s": "n/a"},
    }}
    current = {"channel": {"wall_s": 5.5, "cache_saved_s": -0.5},
               "scaling": {"wall_s": 9.0, "cache_saved_s": 1.25}}
    merged = merge_suites(stale, current)
    assert merged["channel"]["cache_saved_s"] == 0.0      # current wins
    assert merged["channel"]["wall_s"] == 5.5
    assert merged["kernels"]["cache_saved_s"] == 0.0      # stale clamped
    assert merged["fig6"]["cache_saved_s"] == 4.424       # positives kept
    assert merged["scaling"]["cache_saved_s"] == 1.25
    assert merged["weird"]["cache_saved_s"] == "n/a"      # unparsable kept
    assert sanitize_entry({"cache_saved_s": -3})["cache_saved_s"] == 0.0
    assert sanitize_entry({"x": 1}) == {"x": 1}
