"""Channel invariants (core/channel.py): delayed delivery lands exactly at
t + clip(delay, 1, dmax-1) (horizon-edge clipping included), colliding
slots merge by elementwise max (monotone payloads) or add (counters),
fold_state is monotone, and the drop mask is a silent omission. Property
tests drive random delay matrices / payloads (hypothesis; degrades to
fixed-seed cases when it is not installed, matching the repo pattern)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core import channel as ch

DMAX, N, P = 16, 4, 3


def _as_np(x):
    return np.asarray(x)


def _roundtrip_case(seed: int):
    """Random delays (some past the horizon), random send mask: every
    masked message is delivered exactly once, at t + clip(delay, 1, dmax-1),
    with its exact payload; fold_state only ever grows."""
    rng = np.random.RandomState(seed)
    delays = rng.randint(0, 2 * DMAX, size=(N, N))
    payload = rng.uniform(0.0, 100.0, (N, N, P)).astype(np.float32)
    mask = rng.rand(N, N) < 0.7
    c = ch.make_channel(DMAX, N, P)
    c = ch.send(c, jnp.int32(0), jnp.asarray(payload),
                jnp.asarray(delays, jnp.int32), jnp.asarray(mask))
    eff = np.clip(delays, 1, DMAX - 1)
    state = jnp.full((N, N, P), ch.NEG, jnp.float32)
    seen = np.zeros((N, N), bool)
    for t in range(1, DMAX):
        c, flags, pay = ch.deliver(c, jnp.int32(t))
        f = _as_np(flags)
        expect = mask & (eff == t)
        assert np.array_equal(f, expect), f"delivery flags wrong at t={t}"
        assert np.array_equal(_as_np(pay)[f], payload[f]), \
            "payload not delivered verbatim"
        prev = _as_np(state)
        state = ch.fold_state(state, flags, pay)
        assert (_as_np(state) >= prev).all(), "fold_state not monotone"
        seen |= f
    assert np.array_equal(seen, mask), "some masked message never delivered"
    # every slot was popped once: the channel is empty again
    assert not _as_np(c["flag"]).any()
    assert (_as_np(c["buf"]) == ch.NEG).all()


def _collision_case(seed: int):
    """Two same-tick sends landing in one slot merge elementwise-max —
    the delivered message is one the protocol could have received later."""
    rng = np.random.RandomState(seed)
    pa = rng.uniform(0.0, 50.0, (N, N, P)).astype(np.float32)
    pb = rng.uniform(0.0, 50.0, (N, N, P)).astype(np.float32)
    ones = jnp.ones((N, N), jnp.bool_)
    delay = jnp.full((N, N), 5, jnp.int32)
    c = ch.make_channel(DMAX, N, P)
    c = ch.send(c, jnp.int32(0), jnp.asarray(pa), delay, ones)
    c = ch.send(c, jnp.int32(0), jnp.asarray(pb), delay, ones)
    for t in range(1, 6):
        c, flags, pay = ch.deliver(c, jnp.int32(t))
        if t < 5:
            assert not _as_np(flags).any()
    assert _as_np(flags).all()
    assert np.array_equal(_as_np(pay), np.maximum(pa, pb))


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 2 ** 16 - 1))
    def test_send_deliver_roundtrip(seed):
        _roundtrip_case(seed)

    @settings(max_examples=3, deadline=None)
    @given(st.integers(0, 2 ** 16 - 1))
    def test_colliding_slots_merge_max(seed):
        _collision_case(seed)
else:
    def test_send_deliver_roundtrip():
        """Degraded fixed-case variant (hypothesis not installed)."""
        for seed in (0, 1, 12345):
            _roundtrip_case(seed)

    def test_colliding_slots_merge_max():
        """Degraded fixed-case variant (hypothesis not installed)."""
        _collision_case(7)


def test_horizon_edge_clips_to_dmax_minus_1():
    """delay >= dmax is delivered at the horizon (dmax-1), never wraps into
    an earlier slot; delay 0 is bumped to 1 (no same-tick delivery)."""
    ones = jnp.ones((N, N), jnp.bool_)
    pay = jnp.ones((N, N, P), jnp.float32)
    for d in (0, DMAX - 1, DMAX, 3 * DMAX + 2):
        c = ch.make_channel(DMAX, N, P)
        c = ch.send(c, jnp.int32(0), pay, jnp.full((N, N), d, jnp.int32),
                    ones)
        expect_t = int(np.clip(d, 1, DMAX - 1))
        for t in range(1, DMAX):
            c, flags, _ = ch.deliver(c, jnp.int32(t))
            assert _as_np(flags).any() == (t == expect_t), \
                f"delay {d}: delivery at t={t}"


def test_additive_channel_accumulates():
    c = ch.make_channel(DMAX, N, 2, additive=True)
    ones = jnp.ones((N, N), jnp.bool_)
    pay = jnp.full((N, N, 2), 3.0, jnp.float32)
    delay = jnp.full((N, N), 4, jnp.int32)
    c = ch.send(c, jnp.int32(0), pay, delay, ones, additive=True)
    c = ch.send(c, jnp.int32(0), pay, delay, ones, additive=True)
    for t in range(1, 5):
        c, flags, got = ch.deliver(c, jnp.int32(t))
    assert _as_np(flags).all()
    assert (np.asarray(got) == 6.0).all()


# ------------------------------------------------------- packed ring ----

SPEC = ch.RingSpec(ch.ChannelSpec("m1", P),
                   ch.ChannelSpec("fw", 2, additive=True),
                   ch.ChannelSpec("m2", 1))


def _packed_equivalence_case(seed: int, ticks: int = 3 * DMAX,
                             backend: str = "jnp"):
    """The packed ring is bitwise-equal to the seed per-channel substrate
    under random sends, drops, and collisions: same delivered flags and
    payloads every tick, same buffer contents at the end — including a
    channel sent twice per tick (in-slot collisions) and an additive
    counter channel."""
    rng = np.random.RandomState(seed)
    legacy = {"m1": ch.make_channel(DMAX, N, P),
              "fw": ch.make_channel(DMAX, N, 2, additive=True),
              "m2": ch.make_channel(DMAX, N, 1)}
    widths = {"m1": P, "fw": 2, "m2": 1}
    ring = ch.make_ring(SPEC, DMAX, N)
    for t in range(ticks):
        got = ch.ring_deliver(SPEC, ring, jnp.int32(t))
        for name in legacy:
            legacy[name], fl, pay = ch.deliver(legacy[name], jnp.int32(t))
            assert np.array_equal(_as_np(fl), _as_np(got[name][0])), \
                (t, name, "flags")
            assert np.array_equal(_as_np(pay), _as_np(got[name][1])), \
                (t, name, "payload")
        drop = jnp.asarray(rng.rand(N, N) < 0.2)
        sends = []
        # 'm1' sends twice a tick: exercises in-slot max collisions
        for name in ("m1", "fw", "m2", "m1"):
            pay = jnp.asarray(rng.uniform(-1.0, 50.0, (N, N, widths[name])
                                          ).astype(np.float32))
            delay = jnp.asarray(rng.randint(0, 2 * DMAX, (N, N)), jnp.int32)
            mask = jnp.asarray(rng.rand(N, N) < 0.5)
            legacy[name] = ch.send(legacy[name], jnp.int32(t), pay, delay,
                                   mask, additive=(name == "fw"), drop=drop)
            sends.append(ch.Send(name, pay, delay, mask))
        ring = ch.ring_commit(SPEC, ring, jnp.int32(t), sends, drop=drop,
                              backend=backend)
    for name in legacy:
        off = SPEC.offset(name)
        w = widths[name]
        assert np.array_equal(_as_np(legacy[name]["buf"]),
                              _as_np(ring["buf"][..., off:off + w])), name
        assert np.array_equal(_as_np(legacy[name]["flag"]),
                              _as_np(ring["buf"][..., SPEC.flag(name)]) > 0.5
                              ), name


if HAVE_HYPOTHESIS:
    @settings(max_examples=3, deadline=None)
    @given(st.integers(0, 2 ** 16 - 1))
    def test_packed_ring_equals_per_channel_bitwise(seed):
        _packed_equivalence_case(seed)
else:
    def test_packed_ring_equals_per_channel_bitwise():
        """Degraded fixed-case variant (hypothesis not installed)."""
        for seed in (0, 5, 31337):
            _packed_equivalence_case(seed)


def test_ring_spec_layout_and_fill():
    """Interleaved layout: each channel is payload fields + its own flag,
    so one send's whole contribution is contiguous on the field axis."""
    assert SPEC.k == (P + 1) + (2 + 1) + (1 + 1)
    assert SPEC.offset("m1") == 0 and SPEC.flag("m1") == P
    assert SPEC.offset("fw") == P + 1 and SPEC.flag("fw") == P + 3
    assert SPEC.offset("m2") == P + 4 and SPEC.flag("m2") == P + 5
    fill = SPEC.fill()
    assert (fill[:P] == ch.NEG).all()              # max payload fields
    assert fill[P] == 0.0                          # flag field
    assert (fill[P + 1:P + 4] == 0.0).all()        # additive payload + flag
    assert fill[P + 4] == ch.NEG and (fill[P + 5:] == 0.0).all()


def test_drop_mask_is_silent_omission():
    """A dropped link delivers nothing; untouched links are unaffected —
    byte-for-byte the same as an undropped send elsewhere."""
    rng = np.random.RandomState(3)
    pay = rng.uniform(0.0, 10.0, (N, N, P)).astype(np.float32)
    ones = jnp.ones((N, N), jnp.bool_)
    drop = np.zeros((N, N), bool)
    drop[0, 1] = drop[2, 3] = True
    delay = jnp.full((N, N), 2, jnp.int32)
    c = ch.make_channel(DMAX, N, P)
    c = ch.send(c, jnp.int32(0), jnp.asarray(pay), delay, ones,
                drop=jnp.asarray(drop))
    c, f1, _ = ch.deliver(c, jnp.int32(1))
    c, f2, got = ch.deliver(c, jnp.int32(2))
    assert not _as_np(f1).any()
    assert np.array_equal(_as_np(f2), ~drop)
    assert np.array_equal(_as_np(got)[~drop], pay[~drop])
