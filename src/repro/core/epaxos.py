"""EPaxos baseline — analytic model (documented simplification, DESIGN.md §8).

Why a model: the paper itself explains EPaxos's WAN collapse via the revised
EPaxos study (NSDI'21 [45]): with batching, request batches conflict almost
surely, forcing (a) the slow path (second round) and (b) *execution* to wait
for dependency batches from other replicas' instances. We model:

- per-replica sequential instances (no pipelining, §5.2), batch 1000;
- commit latency = fast-quorum RTT + P_slow * majority RTT, with
  P_slow = 1 - (1 - p_conflict)^min(batch, 100);
- execution: global dependency order — executing instance k requires
  learning the previous conflicting instance's commit from its (remote)
  command leader, costing one average one-way delay per link in the chain:
  exec_k = max(commit_k + d_max(origin), exec_{k-1} + d_avg).

The d_avg serial term is the "infinitely growing dependency chains" effect:
when commits outpace 1/d_avg, execution latency diverges — reproducing the
~6.5k tx/s @ <=720ms saturation the paper measures.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.configs.smr import SMRConfig
from repro.obs import monitor as hmon
from repro.obs.decode import host_phases
from repro.obs.trace import TraceLevel
from repro.workloads.analytic import (
    TableRate,
    closed_equilibrium_rate,
    host_rate,
)


def run_epaxos_model(cfg: SMRConfig, rate_tx_s: float, scenario=None,
                     workload=None) -> Dict:
    """``workload``: a repro.workloads.Workload (or None). Open-loop shapes
    modulate the per-origin mean rate over time through the same compiled
    table the simulator reads; a closed-loop workload is approximated at
    its Little's-law equilibrium (run once open to measure latency, then
    re-run at the rate the client pools actually sustain)."""
    wl_rate, closed = host_rate(cfg, workload)
    if closed is not None:
        first = _epaxos_once(cfg, rate_tx_s, wl_rate)
        rate_eff = closed_equilibrium_rate(rate_tx_s, closed,
                                           first["median_ms"],
                                           cfg.n_replicas)
        out = _epaxos_once(cfg, rate_eff, wl_rate)
        out["rate"] = rate_tx_s
        return out
    return _epaxos_once(cfg, rate_tx_s, wl_rate)


def _epaxos_once(cfg: SMRConfig, rate_tx_s: float,
                 wl_rate: Optional[TableRate] = None) -> Dict:
    n = cfg.n_replicas
    d = cfg.delays_ms()                      # one-way ms
    off = d + np.where(np.eye(n, dtype=bool), np.inf, 0)
    rtt = 2 * d
    fast_q = n // 2 + 1                      # thrifty fast quorum incl self
    # per-replica commit duration for one instance
    sorted_rtt = np.sort(np.where(np.eye(n, dtype=bool), np.inf, rtt), axis=1)
    fast_rtt = sorted_rtt[:, fast_q - 2]     # slowest of the needed remote acks
    maj_rtt = sorted_rtt[:, n // 2]
    p_slow = 1.0 - (1.0 - cfg.epaxos_conflict_rate) ** min(cfg.batch_epaxos, 100)
    slot_ms = fast_rtt + p_slow * maj_rtt
    d_avg = float(np.mean(np.where(np.isfinite(off), off, 0))
                  * n / (n - 1))             # mean off-diagonal one-way
    d_max = np.max(d, axis=1)

    sim_ms = cfg.sim_seconds * 1000.0
    lam = rate_tx_s / n / 1000.0             # req per ms per replica
    batch = cfg.batch_epaxos
    # generate instance streams; lam_i varies over time when the workload
    # table is non-trivial (the exact constant-lam path otherwise)
    events = []                    # (create_ms, commit_ms, origin, count, lam)
    for i in range(n):
        t, nxt = 0.0, 0.0
        while t < sim_ms:
            lam_t = lam if wl_rate is None else lam * float(wl_rate.at(t)[i])
            if wl_rate is not None and lam_t <= 0.0:
                # zero-rate window: no arrivals — resume the stream at the
                # window's end instead of dividing by ~0 past the sim
                t = max(wl_rate.next_change_ms(t), t + cfg.tick_ms)
                continue
            fill_ms = batch / max(lam_t, 1e-9)
            start = max(t, nxt)
            create = start + min(fill_ms, cfg.max_batch_ms / 1 + batch / max(lam_t, 1e-9))
            commit = create + slot_ms[i]
            events.append((create, commit, i,
                           min(batch, lam_t * max(fill_ms, cfg.max_batch_ms)),
                           lam_t))
            nxt = commit                     # sequential instances
            t = create
    events.sort(key=lambda e: e[1])
    exec_prev = 0.0
    lat, wt = [], []
    committed = 0.0
    # phase accounting (analytic twin of harness._phase_breakdown):
    # queue = half the batch fill, consensus = the instance's commit
    # round(s), delivery = the dependency-chain execution wait; EPaxos
    # has no separate dissemination layer (batches ride inside PreAccept)
    phases = {"queue": [], "consensus": [], "delivery": []} \
        if cfg.trace_level != TraceLevel.OFF else None
    for create, commit, i, cnt, lam_t in events:
        e = max(commit + d_max[i], exec_prev + p_slow * d_avg)
        exec_prev = e
        if e < sim_ms:
            committed += cnt
            lat.append(e - create + batch / max(lam_t, 1e-9) / 2)
            wt.append(cnt)
            if phases is not None:
                phases["queue"].append(batch / max(lam_t, 1e-9) / 2)
                phases["consensus"].append(commit - create)
                phases["delivery"].append(e - commit)
    lat, wt = np.array(lat), np.array(wt)
    order = np.argsort(lat) if len(lat) else np.array([], int)
    med = p99 = float("nan")
    if len(lat):
        cum = np.cumsum(wt[order]) / wt.sum()
        med = float(lat[order][np.searchsorted(cum, 0.5)])
        p99 = float(lat[order][min(np.searchsorted(cum, 0.99), len(lat) - 1)])
    nbuck = int(np.ceil(sim_ms / 500.0))
    timeline = np.zeros(nbuck)
    for create, commit, i, cnt, _ in events:
        if commit < sim_ms:
            timeline[int(commit // 500)] += cnt
    out = {"protocol": "epaxos", "rate": rate_tx_s,
           "throughput": committed / (sim_ms / 1000.0),
           "median_ms": med, "p99_ms": p99, "committed": committed,
           "timeline": timeline / 0.5}
    if phases is not None:
        out.update(host_phases(phases, wt))
    if hmon.on(cfg.monitor_level):
        # host twin of the device monitor: the model is correct by
        # construction, so the checks are overdraw-style — more committed
        # than offered would be a phantom commit; events sort by commit
        # time, so a backwards execution order would be a prefix break
        offered = rate_tx_s * sim_ms / 1000.0
        execs = [e[1] for e in events]
        starved = sum(1 for create, commit, _, cnt, _ in events
                      if commit >= sim_ms)
        out["monitor"] = hmon.host_verdict(
            violations={
                "commit_once": int(committed > offered * 1.01 + 1.0),
                "prefix": sum(1 for a, b in zip(execs, execs[1:])
                              if b < a),
            },
            gauges={"starved_batches": int(starved),
                    "instances": len(events)},
            level=cfg.monitor_level)
    return out
