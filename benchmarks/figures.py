"""Paper-figure benchmarks (Figs. 6-9) over the WAN simulator.

Each function returns a list of CSV rows (name, us_per_call, derived) where
us_per_call is the median request latency in microseconds and derived packs
protocol/rate/throughput. Simulations are scaled from the paper's 60 s runs
to a few seconds (5x5 deployment unchanged); EXPERIMENTS.md compares against
the paper's headline numbers.

Every sweep goes through the batched experiment engine
(repro.core.experiment.dispatch_sweep): grid points run as pipelined
async dispatches of one canonical compiled program per protocol instead
of one retraced scan per point, with every protocol dispatched before
any result is collected so device execution overlaps host-side
tracing. Sweeps lower at the canonical program signature (one batch
lane, window tables padded, ring horizon floored at 256 slots), so the
fig 6, 7, and 9 suites — same replica count, same sim length — execute
ONE compiled program per protocol: whichever suite runs first pays the
trace, the rest reuse it (pinned by tests/test_compile_cache.py).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Tuple

import numpy as np

from repro.configs.smr import PAPER_CLAIMS, SMRConfig
from repro.core.experiment import SweepSpec, dispatch_sweep
from repro.obs import monitor as obs_monitor
from repro.obs import trace as obs_trace
from repro.obs.export import phases_dict
from repro.scenarios import Crash, Scenario
from repro.scenarios import library as scenario_library
from repro.workloads import library as workload_library

ART = Path(__file__).resolve().parent / "artifacts"

Row = Tuple[str, float, str]

# Flight-recorder level for every suite, read from REPRO_TRACE: the
# default (off) keeps the artifact path byte-identical to an untraced
# build; REPRO_TRACE=counters/full turns the same suites into phase- and
# event-telemetry producers (benchmarks/run.py pops TELEMETRY into the
# per-suite BENCH_core.json blocks).
TRACE_LEVEL = obs_trace.level_from_env()
TELEMETRY: dict = {}

# Health-monitor level for every suite, read from REPRO_MONITOR: off (the
# default) keeps the artifact path byte-identical; gauges/full turn every
# sweep into an invariant-checked run whose per-suite verdicts
# (benchmarks/run.py pops VERDICTS into BENCH_core.json and the
# BENCH_history.jsonl ledger) gate CI.
MONITOR_LEVEL = obs_monitor.level_from_env()
VERDICTS: dict = {}


def _cfg(**kw) -> SMRConfig:
    return SMRConfig(trace_level=TRACE_LEVEL,
                     monitor_level=MONITOR_LEVEL, **kw)


def _tele_phases(suite: str, key: str, r: dict) -> dict | None:
    """Record one result's phase breakdown into the suite telemetry;
    returns the phases dict (None when untraced) for the artifact JSON."""
    ph = phases_dict(r)
    if ph is not None:
        t = TELEMETRY.setdefault(suite, {"trace_level": TRACE_LEVEL,
                                         "phases": {}})
        t["phases"][key] = ph
    return ph


def _tele_monitor(suite: str, key: str, r: dict) -> dict | None:
    """Fold one result's monitor verdict into the suite-level verdict;
    returns the point verdict (None when the monitor is off)."""
    v = obs_monitor.verdict(r)
    if v is None:
        return None
    agg = VERDICTS.setdefault(suite, {"level": v["level"], "ok": True,
                                      "points": 0, "violations": {}})
    agg["points"] += 1
    agg["ok"] = agg["ok"] and v["ok"]
    for k, c in v["violations"].items():
        agg["violations"][k] = agg["violations"].get(k, 0) + c
    return v


def _row(name: str, med_ms: float, **derived) -> Row:
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    return (name, med_ms * 1000.0, d)


def fig6_throughput_latency(sim_seconds: float = 4.0) -> List[Row]:
    """Best-case WAN performance, 5 replicas (Fig. 6). Each protocol's rate
    sweep runs as one batched grid."""
    cfg = _cfg(sim_seconds=sim_seconds)
    sweeps = {
        "mandator-sporades": [50_000, 150_000, 300_000, 450_000],
        "mandator-paxos": [50_000, 150_000, 300_000, 450_000],
        "multipaxos": [10_000, 30_000, 50_000, 100_000],
        "epaxos": [2_000, 5_000, 10_000, 20_000],
        "rabia": [200, 500, 1_000, 2_000],
    }
    rows: List[Row] = []
    results = {}
    # dispatch every protocol before collecting any: each program's device
    # execution overlaps the next one's trace/lowering
    pending = {proto: dispatch_sweep(proto, cfg, SweepSpec(rates=tuple(rs)))
               for proto, rs in sweeps.items()}
    phases: dict = {}
    for proto, p in pending.items():
        best = 0.0
        for r in p.collect():
            rows.append(_row(f"fig6/{proto}@{round(r['rate'])}",
                             r["median_ms"],
                             tput=round(r["throughput"]),
                             p99_ms=round(r["p99_ms"], 1)))
            ph = _tele_phases("fig6", f"{proto}@{round(r['rate'])}", r)
            if ph is not None:
                phases.setdefault(proto, {})[str(round(r["rate"]))] = ph
            _tele_monitor("fig6", f"{proto}@{round(r['rate'])}", r)
            # saturation throughput under the paper's ~1s (5s DDoS) bound
            if r["median_ms"] < 1_000 and r["throughput"] > best:
                best = r["throughput"]
        results[proto] = best
    if phases:
        results["_phases"] = phases
    (ART / "fig6.json").write_text(json.dumps(results, indent=1))
    return rows


def fig7_crash(sim_seconds: float = 4.0) -> List[Row]:
    """Leader crash mid-run (Fig. 7): throughput timeline."""
    cfg = _cfg(sim_seconds=sim_seconds)
    # leader of view 0 crashes permanently mid-run (exact seed-era
    # crash-schedule semantics: Crash with no recovery)
    spec = SweepSpec(rates=(100_000,),
                     scenarios=(Scenario("leader-crash", (
                         Crash(start_s=sim_seconds / 2, targets=(0,)),)),))
    rows: List[Row] = []
    out = {}
    pending = {proto: dispatch_sweep(proto, cfg, spec)
               for proto in ("mandator-sporades", "mandator-paxos")}
    phases: dict = {}
    for proto, p in pending.items():
        r = p.collect()[0]
        tl = [round(float(x)) for x in r["timeline"]]
        out[proto] = tl
        ph = _tele_phases("fig7", proto, r)
        if ph is not None:
            phases[proto] = ph
        _tele_monitor("fig7", proto, r)
        post = np.asarray(r["timeline"])[-2:]
        rows.append(_row(f"fig7/{proto}", r["median_ms"],
                         tput=round(r["throughput"]),
                         recovered=int(post.max() > 0),
                         timeline="|".join(map(str, tl))))
    if phases:
        out["_phases"] = phases
    (ART / "fig7.json").write_text(json.dumps(out, indent=1))
    return rows


def fig8_ddos(sim_seconds: float = 4.0) -> List[Row]:
    """Targeted-minority DDoS (Fig. 8)."""
    cfg = _cfg(sim_seconds=sim_seconds)
    # the curated §5.5 attack (same seeded attacked-minority draw stream
    # as the seed-era DDoS schedule)
    attack = scenario_library.get("paper-ddos", sim_seconds)
    rows: List[Row] = []
    out = {}
    plan = (("mandator-sporades", 300_000), ("mandator-paxos", 300_000),
            ("multipaxos", 50_000), ("epaxos", 10_000))
    pending = {
        proto: dispatch_sweep(
            proto, cfg,
            SweepSpec(rates=(rate,)) if proto == "epaxos"
            else SweepSpec(rates=(rate,), scenarios=(attack,)))
        for proto, rate in plan}
    for proto, p in pending.items():
        r = p.collect()[0]
        if proto == "epaxos":
            # analytic baseline: DDoS modeled as doubled effective RTTs
            r["throughput"] *= 0.5
            r["median_ms"] *= 2.0
        out[proto] = {"tput": r["throughput"], "med_ms": r["median_ms"]}
        ph = _tele_phases("fig8", proto, r)
        if ph is not None:
            out[proto]["phases"] = ph
        _tele_monitor("fig8", proto, r)
        rows.append(_row(f"fig8/{proto}", r["median_ms"],
                         tput=round(r["throughput"])))
    (ART / "fig8.json").write_text(json.dumps(out, indent=1))
    return rows


def fig9_scalability(sim_seconds: float = 3.0) -> List[Row]:
    """3 -> 9 replicas, Mandator-Sporades (Fig. 9). Replica count changes the
    array shapes, so each n is its own compiled program (cfg is static)."""
    rows: List[Row] = []
    out = {}
    pending = {n: dispatch_sweep("mandator-sporades",
                                 _cfg(n_replicas=n,
                                      sim_seconds=sim_seconds),
                                 SweepSpec(rates=(60_000 * n,)))
               for n in (3, 5, 7, 9)}
    for n, p in pending.items():
        r = p.collect()[0]
        out[n] = {"tput": r["throughput"], "med_ms": r["median_ms"]}
        ph = _tele_phases("fig9", f"n={n}", r)
        if ph is not None:
            out[n]["phases"] = ph
        _tele_monitor("fig9", f"n={n}", r)
        rows.append(_row(f"fig9/n={n}", r["median_ms"],
                         tput=round(r["throughput"])))
    (ART / "fig9.json").write_text(json.dumps(out, indent=1))
    return rows


def robustness(sim_seconds: float = 4.0) -> List[Row]:
    """Protocol × scenario robustness matrix over the curated adversary
    library (scenarios/library.py). Each protocol's whole
    scenario × rate grid is ONE batched sweep (one compiled program), so
    adding a scenario costs a vmap lane, not a retrace."""
    cfg = _cfg(sim_seconds=sim_seconds)
    lib = scenario_library.scenarios(sim_seconds, cfg.n_replicas)
    sweeps = {
        "mandator-sporades": (50_000, 200_000),
        "mandator-paxos": (50_000, 200_000),
        "multipaxos": (10_000, 30_000),
    }
    rows: List[Row] = []
    matrix: dict = {}
    names = list(lib)
    fin = lambda x: float(x) if np.isfinite(x) else None  # noqa: E731
    specs = {proto: SweepSpec(rates=rates, scenarios=tuple(lib.values()))
             for proto, rates in sweeps.items()}
    pending = {proto: dispatch_sweep(proto, cfg, spec)
               for proto, spec in specs.items()}
    for proto, spec in specs.items():
        matrix[proto] = {s: {} for s in names}
        for r, (rate, _, fi, _) in zip(pending[proto].collect(),
                                       spec.points()):
            scen = names[fi]
            cell = {
                "tput": fin(r["throughput"]), "med_ms": fin(r["median_ms"]),
                "p99_ms": fin(r["p99_ms"]), "committed": fin(r["committed"]),
            }
            mv = _tele_monitor("robustness", f"{proto}@{round(rate)}/{scen}",
                               r)
            if mv is not None:
                cell["monitor"] = {"ok": mv["ok"],
                                   "violations": mv["violations"]}
            matrix[proto][scen][str(round(rate))] = cell
            rows.append(_row(f"robustness/{proto}@{round(rate)}/{scen}",
                             r["median_ms"], tput=round(r["throughput"]),
                             committed=round(r["committed"])))
    (ART / "robustness.json").write_text(json.dumps(matrix, indent=1))
    return rows


def workload_matrix(sim_seconds: float = 4.0) -> List[Row]:
    """Protocol × workload × scenario matrix over the curated traffic
    library (workloads/library.py). Each scan protocol's whole
    workload × scenario grid is ONE batched sweep (one compiled program) —
    adding a traffic shape costs a vmap lane, not a retrace. The analytic
    baselines (epaxos/rabia) consume the same compiled rate tables
    host-side, so all six protocols appear in the matrix."""
    cfg = _cfg(sim_seconds=sim_seconds)
    wlib = workload_library.workloads(sim_seconds, cfg.n_replicas)
    slib = scenario_library.scenarios(sim_seconds, cfg.n_replicas)
    rates = {
        "mandator-sporades": 200_000, "mandator-paxos": 200_000,
        "mandator": 200_000, "multipaxos": 30_000,
        "epaxos": 8_000, "rabia": 800,
    }
    rows: List[Row] = []
    matrix: dict = {}
    wl_names = list(wlib)
    fin = lambda x: float(x) if np.isfinite(x) else None  # noqa: E731
    # the analytic models are fault-blind: running them under an
    # adversary would duplicate the baseline column and present it as
    # a measured result, so they only get the baseline scenario
    scen_plan = {proto: (("baseline",) if proto in ("epaxos", "rabia")
                         else ("baseline", "paper-ddos"))
                 for proto in rates}
    specs = {proto: SweepSpec(rates=(rate,),
                              scenarios=tuple(slib[s]
                                              for s in scen_plan[proto]),
                              workloads=tuple(wlib.values()))
             for proto, rate in rates.items()}
    pending = {proto: dispatch_sweep(proto, cfg, spec)
               for proto, spec in specs.items()}
    for proto, spec in specs.items():
        scen_names = scen_plan[proto]
        matrix[proto] = {w: {} for w in wl_names}
        for r, (_, _, fi, wi) in zip(pending[proto].collect(),
                                     spec.points()):
            wname, sname = wl_names[wi], scen_names[fi]
            cell = {"tput": fin(r["throughput"]),
                    "med_ms": fin(r["median_ms"]),
                    "p99_ms": fin(r["p99_ms"]),
                    "committed": fin(r["committed"])}
            if "origin_median_ms" in r:
                cell["origin_med_ms"] = [fin(x)
                                         for x in r["origin_median_ms"]]
            if "inflight_max" in r:
                cell["inflight_max"] = [fin(x) for x in r["inflight_max"]]
            mv = _tele_monitor("workloads", f"{proto}/{wname}/{sname}", r)
            if mv is not None:
                cell["monitor"] = {"ok": mv["ok"],
                                   "violations": mv["violations"]}
            matrix[proto][wname][sname] = cell
            rows.append(_row(f"workloads/{proto}/{wname}/{sname}",
                             r["median_ms"], tput=round(r["throughput"]),
                             committed=round(r["committed"])))
    (ART / "workloads.json").write_text(json.dumps(matrix, indent=1))
    return rows


def scaling_curve(sim_seconds: float = 0.25, n_points: int = 1024,
                  device_counts=None) -> List[Row]:
    """Points/sec-vs-devices curve of the mesh-sharded sweep engine
    (ISSUE 10 / ROADMAP "millions of users" axis): one
    workload × scenario × rate × seed grid of ``n_points`` points, run
    through ``dispatch_sweep(mesh=...)`` at each device count. On a
    stock CPU runner this needs
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in the
    environment (before jax initializes) to expose >1 host device.

    The suite is also the sharded-parity gate: per-point scalar metrics
    must be BITWISE identical across every device count and against the
    legacy per-point dispatch path (checked on the grid's first
    rate × seed slice) — any drift raises, failing the suite and CI."""
    from repro.core import experiment
    from repro.distributed import mesh as dmesh

    proto = "mandator-sporades"
    cfg = _cfg(sim_seconds=sim_seconds)
    wlib = workload_library.workloads(sim_seconds, cfg.n_replicas)
    slib = scenario_library.scenarios(sim_seconds, cfg.n_replicas)
    workloads = tuple(wlib[w] for w in ("poisson-open", "onoff-burst",
                                        "diurnal", "flash-crowd"))
    scens = (slib["baseline"], slib["paper-ddos"])
    n_rates = max(1, n_points // (16 * len(workloads) * len(scens)))
    rates = tuple(np.linspace(50_000, 400_000, n_rates))
    seeds = tuple(range(max(1, n_points
                            // (n_rates * len(workloads) * len(scens)))))
    spec = SweepSpec(rates=rates, seeds=seeds, scenarios=scens,
                     workloads=workloads)
    if device_counts is None:
        device_counts = dmesh.device_counts()
    import time as _time
    rows: List[Row] = []
    curve = []
    baseline = None
    scalar_keys = ("throughput", "median_ms", "p99_ms", "committed")
    same = lambda a, b: a == b or (np.isnan(a) and np.isnan(b))  # noqa: E731
    for d in device_counts:
        t0 = _time.perf_counter()
        pending = dispatch_sweep(proto, cfg, spec, mesh=dmesh.grid_mesh(d))
        t_dispatch = _time.perf_counter() - t0
        t1 = _time.perf_counter()
        res = pending.collect()
        t_run = _time.perf_counter() - t1
        wall = t_dispatch + t_run
        if baseline is None:
            baseline = res
        else:
            for i, (a, b) in enumerate(zip(baseline, res)):
                for k in scalar_keys:
                    if not same(a[k], b[k]):
                        raise AssertionError(
                            f"sharded parity broke: point {i} {k}: "
                            f"d=1 {a[k]!r} vs d={d} {b[k]!r}")
        curve.append({"devices": int(d), "points": spec.size,
                      "dispatch_s": round(t_dispatch, 3),
                      "run_s": round(t_run, 3), "wall_s": round(wall, 3),
                      "points_per_s": round(spec.size / max(t_run, 1e-9),
                                            1)})
        rows.append(_row(f"scaling/d={d}", 0.0, points=spec.size,
                         run_s=round(t_run, 2),
                         pts_per_s=round(spec.size / max(t_run, 1e-9))))
    # legacy-vs-sharded parity on the grid's first rate x seed slice
    # (the full grid through the per-point loop would dwarf the suite)
    sub = SweepSpec(rates=rates[:1], seeds=seeds[:1], scenarios=scens,
                    workloads=workloads)
    legacy = dispatch_sweep(proto, cfg, sub).collect()
    for i, (a, b) in enumerate(zip(legacy, baseline)):
        for k in scalar_keys:
            if not same(a[k], b[k]):
                raise AssertionError(
                    f"sharded-vs-legacy parity broke: point {i} {k}: "
                    f"legacy {a[k]!r} vs sharded {b[k]!r}")
    block = {"protocol": proto, "sim_seconds": sim_seconds,
             "grid": {"rates": len(rates), "seeds": len(seeds),
                      "scenarios": len(scens),
                      "workloads": len(workloads)},
             "sketch_bins": int(np.asarray(
                 baseline[0]["sketch"]["v"]).shape[0]),
             "parity": "bitwise", "curve": curve}
    (ART / "scaling.json").write_text(json.dumps(block, indent=1))
    SCALING["scaling"] = block
    return rows


# run.py pops this into the scaling suite's BENCH_core.json entry
SCALING: dict = {}


def paper_comparison() -> List[Row]:
    """Summarize sim-vs-paper headline numbers (fills EXPERIMENTS.md)."""
    rows: List[Row] = []
    f6 = json.loads((ART / "fig6.json").read_text()) \
        if (ART / "fig6.json").exists() else {}
    claims = {
        "mandator-sporades": PAPER_CLAIMS["mandator_sporades_tput"],
        "mandator-paxos": PAPER_CLAIMS["mandator_paxos_tput"],
        "multipaxos": PAPER_CLAIMS["multipaxos_tput"],
        "epaxos": PAPER_CLAIMS["epaxos_tput"],
        "rabia": PAPER_CLAIMS["rabia_tput"],
    }
    for proto, claim in claims.items():
        ours = f6.get(proto, 0.0)
        rows.append(_row(f"paper/{proto}", 0.0, sim_tput=round(ours),
                         paper_tput=claim,
                         ratio=round(ours / claim, 2) if claim else 0))
    return rows
