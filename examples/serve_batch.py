"""Batched serving across modalities: decoder LM (qwen3), audio-token
decoder (musicgen stub frontend), and a VLM with cross-attention memory.

  PYTHONPATH=src python examples/serve_batch.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import serve


def main() -> None:
    for arch in ("qwen3-14b", "musicgen-medium", "llama-3.2-vision-11b"):
        serve(arch, batch=2, prompt_len=8, gen=12)


if __name__ == "__main__":
    main()
