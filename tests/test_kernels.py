"""Per-kernel validation: shape/dtype sweeps, interpret-mode kernels vs the
pure-jnp oracles, plus custom-VJP correctness of the jnp fast paths."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,s,h,kh,d", [
    (2, 128, 4, 2, 32),
    (1, 256, 8, 8, 64),
    (2, 96, 6, 3, 16),      # non-multiple seq -> padding path
    (1, 64, 4, 1, 32),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, kh, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kh, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kh, d), dtype)
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=True)
    tol = 5e-6 if dtype == jnp.float32 else 2e-2
    assert out.shape == ref.shape and out.dtype == dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("shape", [(4, 16, 64), (3, 7, 32), (2, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("residual", [False, True])
def test_rmsnorm_sweep(shape, dtype, residual):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], shape, dtype)
    r = jax.random.normal(ks[1], shape, dtype) if residual else None
    w = jax.random.normal(ks[2], shape[-1:], dtype)
    out = rmsnorm(x, w, residual=r)
    ref = rmsnorm_ref(x, w, residual=r)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("b,s,di,n", [(2, 64, 32, 8), (1, 48, 16, 4),
                                      (2, 128, 8, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssm_scan_sweep(b, s, di, n, dtype):
    ks = jax.random.split(KEY, 6)
    x = (jax.random.normal(ks[0], (b, s, di)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) - 1).astype(dtype)
    B = jax.random.normal(ks[2], (b, s, n), dtype)
    C = jax.random.normal(ks[3], (b, s, n), dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.3)
    D = jax.random.normal(ks[5], (di,))
    out = ssm_scan(x, dt, B, C, A, D, bd=16, chunk=16)
    ref = ssm_scan_ref(x, dt, B, C, A, D)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


def test_flash_chunked_vjp_matches_dense():
    from repro.models.layers import dense_attention, flash_chunked
    b, s, h, kh, d = 2, 128, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    g1 = jax.grad(lambda *a: (flash_chunked(*a, True, 32) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (dense_attention(*a, causal=True) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b_))) < 1e-4


def test_selective_scan_vjp_matches_autodiff():
    from repro.models.ssm import _selective_scan
    b, s, di, n = 2, 32, 8, 4
    ks = jax.random.split(KEY, 6)
    args = (jax.random.normal(ks[0], (b, s, di)) * 0.5,
            jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) - 1),
            jax.random.normal(ks[2], (b, s, n)),
            jax.random.normal(ks[3], (b, s, n)),
            -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.3),
            jax.random.normal(ks[5], (di,)))
    g1 = jax.grad(lambda *a: (_selective_scan(*a, 8) ** 2).sum(),
                  argnums=tuple(range(6)))(*args)
    g2 = jax.grad(lambda *a: (ssm_scan_ref(*a) ** 2).sum(),
                  argnums=tuple(range(6)))(*args)
    for x, y in zip(g1, g2):
        denom = max(1.0, float(jnp.max(jnp.abs(y))))
        assert float(jnp.max(jnp.abs(x - y))) / denom < 1e-4


@pytest.mark.parametrize("b,h,kh,s,d", [
    (2, 4, 2, 256, 32),
    (1, 8, 8, 128, 64),     # MHA
    (2, 4, 1, 512, 16),     # MQA
])
def test_decode_attention_sweep(b, h, kh, s, d):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kh, s, d))
    v = jax.random.normal(ks[2], (b, kh, s, d))
    kv_len = jnp.arange(1, b + 1, dtype=jnp.int32) * (s // (b + 1) + 1)
    out = decode_attention(q, k, v, kv_len, bs=64)
    ref = decode_attention_ref(q, k, v, kv_len)
    assert float(jnp.max(jnp.abs(out - ref))) < 5e-6


@pytest.mark.parametrize("seed", [0, 7])
def test_channel_ring_commit_interpret_matches_ref(seed):
    """Pallas dense ring-commit kernel (interpret mode) is bitwise-equal to
    the pure-jnp scatter oracle over random tick traffic — max-merged and
    additive channels, drops, in-slot collisions, and the slot-clear."""
    import numpy as np

    from repro.core import channel as ch

    rng = np.random.RandomState(seed)
    dmax, n = 32, 5
    spec = ch.RingSpec(ch.ChannelSpec("a", 2),
                       ch.ChannelSpec("fw", 2, additive=True),
                       ch.ChannelSpec("b", 3))
    ring_ref = ch.make_ring(spec, dmax, n)
    ring_pal = ch.make_ring(spec, dmax, n)
    for t in range(2 * dmax):
        drop = jnp.asarray(rng.rand(n, n) < 0.2)
        sends = []
        for name, w in (("a", 2), ("fw", 2), ("b", 3), ("a", 2)):
            pay = jnp.asarray(rng.uniform(-1.0, 50.0, (n, n, w)
                                          ).astype(np.float32))
            delay = jnp.asarray(rng.randint(0, 2 * dmax, (n, n)), jnp.int32)
            mask = jnp.asarray(rng.rand(n, n) < 0.5)
            sends.append(ch.Send(name, pay, delay, mask))
        ring_ref = ch.ring_commit(spec, ring_ref, jnp.int32(t), sends,
                                  drop=drop, backend="jnp")
        ring_pal = ch.ring_commit(spec, ring_pal, jnp.int32(t), sends,
                                  drop=drop, backend="pallas-interpret")
        np.testing.assert_array_equal(np.asarray(ring_ref["buf"]),
                                      np.asarray(ring_pal["buf"]),
                                      err_msg=f"t={t}")


def test_channel_backend_rejects_unknown():
    from repro.kernels.channel_ring.ops import resolve_backend
    with pytest.raises(ValueError, match="channel backend"):
        resolve_backend("cuda")
    assert resolve_backend("ref") == "jnp"


def test_decode_attention_matches_model_decode_path():
    """Kernel agrees with the model's cache attention (dense path)."""
    from repro.kernels.decode_attention.ref import decode_attention_ref
    from repro.models.layers import dense_attention
    b, h, kh, s, d = 2, 4, 2, 64, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    kv_len = jnp.array([40, 64], jnp.int32)
    a = dense_attention(q, k, v, causal=False, kv_len=kv_len)[:, 0]
    r = decode_attention_ref(q[:, 0], k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), kv_len)
    assert float(jnp.max(jnp.abs(a - r))) < 5e-6
