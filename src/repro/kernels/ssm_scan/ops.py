"""Jit'd wrapper for the selective-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssm_scan.kernel import ssm_scan_pallas


@partial(jax.jit, static_argnames=("bd", "chunk", "interpret"))
def ssm_scan(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
             A: jax.Array, D: jax.Array, *, bd: int = 256, chunk: int = 128,
             interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    di, s = x.shape[2], x.shape[1]
    while di % bd and bd > 1:
        bd //= 2
    while s % chunk and chunk > 1:
        chunk //= 2
    return ssm_scan_pallas(x, dt, B, C, A, D, bd=bd, chunk=chunk,
                           interpret=interpret)
