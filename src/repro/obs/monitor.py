"""On-device consensus health monitor: invariant checks + resource gauges.

The paper's claim is not just that Mandator/Sporades is *fast* under
asynchrony and DDoS — it is that the protocols stay *safe* (agreement,
log-prefix order, commit-once, monotone views) and *live* (commits resume
within a bounded window once the network heals).  The flight recorder
(obs/trace.py) records what happened; this module checks that what
happened was correct, per tick, on device, inside the same
``jax.lax.scan`` carry — so a whole sweep grid vmaps the monitor exactly
like it vmaps the channel rings.

Gating is *static* and mirrors ``trace_level``: ``SMRConfig.monitor_level``
is a frozen-dataclass field and cfg is a jit static argument, so at
``MonitorLevel.OFF`` (the default) ``init_monitor`` returns None, nothing
enters the carry, and the compiled program is instruction-identical to an
unmonitored build (tests/test_monitor.py pins the outputs bitwise).
``GAUGES`` carries only the cheap resource reductions; ``FULL`` adds the
safety/liveness violation counters.

What is checked, per tick (violation counters count *violating ticks*):

- ``agreement``   — the committed vector clocks of every pair of alive
                    replicas are comparable (one dominates the other):
                    no two alive replicas commit divergent prefixes.
- ``prefix``      — each replica's committed state never decreases
                    (elementwise on the committed VC, and on the monotone
                    commit key/slot): a commit is never retracted.
- ``commit_once`` — the cluster-wide committed round per origin never
                    exceeds what that origin has created: nothing commits
                    a batch that was never formed (no phantom re-commit).
- ``view_monotone`` — per-replica views/rounds never decrease.
- ``inflight_cap`` — closed-loop clients never exceed their admission cap
                    (skipped for multipaxos, whose per-origin completion
                    split is a pro-rata estimate, not an exact count).
- ``stall``       — commit-stall watchdog: consecutive ticks where the
                    cluster is *healthy* (some alive replica sees a
                    quorum of alive, un-partitioned peers), work is
                    *pending*, and yet no commit lands, exceed a
                    scenario-aware grace window (``stall_grace_ticks``:
                    derived from the view timeout and the env delay
                    tables, so a DDoS that slows every link widens the
                    window it is judged by — and a healed partition must
                    resume commits within it).

Resource gauges (all levels > off): max/mean packed-ring slot occupancy,
cumulative dropped-send counts, per-replica closed-loop inflight
high-water marks, per-origin dissemination-starvation high water (batches
formed but not yet stable), plus 500ms-bucketed occupancy/drop timelines
that obs/export.py renders as Perfetto counter tracks.

Host side: ``verdict`` folds a collected sweep point into a plain
verdict dict, ``HostMonitor`` is the twin for the pure-python runtime
drivers (runtime/*_rt.py), and ``host_verdict`` builds the same schema
for the analytic epaxos/rabia models.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import netsim


class MonitorLevel:
    """Static monitor gate. OFF compiles the monitor out entirely; GAUGES
    keeps only the resource reductions; FULL adds the invariant checks."""
    OFF = "off"
    GAUGES = "gauges"
    FULL = "full"
    ORDER = (OFF, GAUGES, FULL)

    @staticmethod
    def check(level: str) -> str:
        if level not in MonitorLevel.ORDER:
            raise ValueError(f"monitor_level {level!r}; expected one of "
                             f"{MonitorLevel.ORDER}")
        return level


MONITOR_ENV = "REPRO_MONITOR"  # benchmarks read the level from the env


def level_from_env(default: str = MonitorLevel.OFF) -> str:
    """Monitor level from ``REPRO_MONITOR`` (off/gauges/full); benchmarks
    use this so the default artifact path stays byte-identical to an
    unmonitored build while ``REPRO_MONITOR=full`` turns the same suites
    into invariant checkers."""
    return MonitorLevel.check(os.environ.get(MONITOR_ENV, default))


def on(level: str) -> bool:
    return MonitorLevel.check(level) != MonitorLevel.OFF


# Violation taxonomy; declaration order is the index into ``mon["viol"]``.
VIOLATIONS = ("agreement", "prefix", "commit_once", "view_monotone",
              "inflight_cap", "stall")

# Perfetto counter-track bucket width, matching the metric timelines.
BUCKET_MS = 500.0


def n_buckets(n_ticks: int, tick_ms: float) -> int:
    return max(1, int(np.ceil(n_ticks * tick_ms / BUCKET_MS)))


def stall_grace_ticks(cfg, env) -> jax.Array:
    """Watchdog grace window in ticks. An explicit
    ``cfg.monitor_stall_grace_ms`` pins it; otherwise it is derived per
    sweep point from the view timeout plus the scenario's own delay
    tables (``env["delay_tab"]`` is a traced leaf, so a vmapped grid gets
    a per-lane window) — generous on purpose: the watchdog flags silent
    stalls, not slow commits."""
    if cfg.monitor_stall_grace_ms > 0:
        return jnp.float32(cfg.monitor_stall_grace_ms / cfg.tick_ms)
    static_delay = float(np.max(cfg.delays_ms())) / cfg.tick_ms
    to_ticks = cfg.view_timeout_ms / cfg.tick_ms
    extra = jnp.max(env["delay_tab"]).astype(jnp.float32)  # scenario ticks
    return jnp.float32(4.0 * to_ticks + 8.0 * static_delay + 128.0) \
        + 8.0 * extra


def init_monitor(cfg, n_ticks: int, views: Dict) -> Optional[Dict]:
    """Monitor carry state, or None at MonitorLevel.OFF (so carrying it in
    the scan state dict is structurally free when monitoring is off).
    ``views`` is the t=0 projection from ``harness._monitor_views`` — its
    keys decide which prev-state slots exist for this protocol."""
    level = MonitorLevel.check(cfg.monitor_level)
    if level == MonitorLevel.OFF:
        return None
    n = cfg.n_replicas
    nb = n_buckets(n_ticks, cfg.tick_ms)
    mon: Dict[str, jax.Array] = {
        "ring_occ_max": jnp.float32(0.0),
        "ring_occ_sum": jnp.float32(0.0),
        "dropped_sends": jnp.zeros((n,), jnp.int32),
        "inflight_hwm": jnp.zeros((n,), jnp.float32),
        "starved_max": jnp.zeros((n,), jnp.int32),
        "occ_tl": jnp.zeros((nb,), jnp.float32),
        "drop_tl": jnp.zeros((nb,), jnp.float32),
    }
    if level == MonitorLevel.FULL:
        mon["viol"] = jnp.zeros((len(VIOLATIONS),), jnp.int32)
        mon["stall_run"] = jnp.int32(0)
        mon["stall_max"] = jnp.int32(0)
        prev: Dict[str, jax.Array] = {
            "commit_tot": jnp.asarray(views["commit_tot"], jnp.float32)}
        for k in ("cvc", "commit_seq", "view"):
            if views.get(k) is not None:
                prev[k] = views[k]
        mon["prev"] = prev
    return mon


def update(mon: Optional[Dict], t: jax.Array, cfg, env, views: Dict,
           grace_ticks: jax.Array, wlt: Optional[Dict] = None,
           inflight: Optional[jax.Array] = None,
           check_cap: bool = False) -> Optional[Dict]:
    """One monitor tick. ``views`` is the protocol-state projection built
    by ``harness._monitor_views`` (see there for the per-protocol key
    map); None monitor state (level off) passes straight through, so call
    sites need no level branching of their own."""
    if mon is None:
        return None
    mon = dict(mon)
    # ---- resource gauges (all levels > off) -----------------------------
    occ = views["ring_occ"]
    dropped = views["dropped"]
    mon["ring_occ_max"] = jnp.maximum(mon["ring_occ_max"], occ)
    mon["ring_occ_sum"] = mon["ring_occ_sum"] + occ
    mon["dropped_sends"] = mon["dropped_sends"] + dropped
    nb = mon["occ_tl"].shape[0]
    b = jnp.clip((t * (cfg.tick_ms / BUCKET_MS)).astype(jnp.int32), 0,
                 nb - 1)
    mon["occ_tl"] = mon["occ_tl"].at[b].max(occ)
    mon["drop_tl"] = mon["drop_tl"].at[b].add(
        jnp.sum(dropped).astype(jnp.float32))
    mon["starved_max"] = jnp.maximum(
        mon["starved_max"],
        (views["formed"] - views["stable"]).astype(jnp.int32))
    if inflight is not None:
        mon["inflight_hwm"] = jnp.maximum(mon["inflight_hwm"],
                                          jnp.asarray(inflight, jnp.float32))
    if "viol" not in mon:
        return mon
    # ---- safety invariants ----------------------------------------------
    alive = netsim.alive(env, t)
    prev = dict(mon["prev"])
    bad: Dict[str, jax.Array] = {}
    cvc = views.get("cvc")
    if cvc is not None:
        # agreement: committed VCs of alive pairs must be comparable —
        # one replica's committed prefix dominates the other's.
        ge = jnp.all(cvc[:, None, :] >= cvc[None, :, :], axis=-1)  # [n, n]
        both = alive[:, None] & alive[None, :]
        bad["agreement"] = jnp.any(both & ~(ge | ge.T))
        bad["prefix"] = jnp.any(cvc < prev["cvc"])
        prev["cvc"] = cvc
    seq = views.get("commit_seq")
    if seq is not None:
        dec = jnp.any(seq < prev["commit_seq"])
        bad["prefix"] = bad.get("prefix", jnp.asarray(False)) | dec
        prev["commit_seq"] = seq
    # commit-once / no phantom commit: the cluster-max committed round per
    # origin never exceeds what that origin has formed.
    claim = jnp.max(cvc, axis=0) if cvc is not None else views["stable"]
    bad["commit_once"] = jnp.any(claim > views["formed"])
    view = views.get("view")
    if view is not None:
        bad["view_monotone"] = jnp.any(view < prev["view"])
        prev["view"] = view
    if check_cap and inflight is not None and wlt is not None:
        over = (jnp.asarray(inflight, jnp.float32) >
                jnp.asarray(wlt["cap"], jnp.float32) + 0.5)
        bad["inflight_cap"] = jnp.any(over & (wlt["closed"] > 0))
    # ---- liveness: commit-stall watchdog --------------------------------
    commit_tot = jnp.asarray(views["commit_tot"], jnp.float32)
    progress = commit_tot > prev["commit_tot"]
    prev["commit_tot"] = commit_tot
    drop = netsim.link_drop(env, t)
    conn = (alive[:, None] & alive[None, :] & ~drop & ~drop.T)
    conn = conn | (jnp.eye(alive.shape[0], dtype=bool) & alive[:, None])
    degree = jnp.sum(conn, axis=1)
    quorum = cfg.n_replicas // 2 + 1
    healthy = jnp.any(degree >= quorum)
    armed = healthy & views["pending"] & ~progress
    run = jnp.where(armed, mon["stall_run"] + 1, jnp.int32(0))
    bad["stall"] = run.astype(jnp.float32) > grace_ticks
    mon["stall_run"] = run
    mon["stall_max"] = jnp.maximum(mon["stall_max"], run)
    mon["viol"] = mon["viol"] + jnp.stack(
        [jnp.asarray(bad.get(name, False)).astype(jnp.int32)
         for name in VIOLATIONS])
    mon["prev"] = prev
    return mon


def public_view(mon: Optional[Dict], n_ticks: int) -> Optional[Dict]:
    """The monitor leaves worth surfacing out of the scan (everything but
    the prev-state scratch), with the running occupancy sum folded into a
    mean."""
    if mon is None:
        return None
    out = {k: v for k, v in mon.items() if k not in ("prev", "stall_run")}
    out["ring_occ_mean"] = out.pop("ring_occ_sum") / float(max(n_ticks, 1))
    return out


# --------------------------------------------------------------------------
# Host side: verdicts
# --------------------------------------------------------------------------

def host_verdict(violations: Optional[Dict[str, int]] = None,
                 gauges: Optional[Dict] = None,
                 level: str = MonitorLevel.FULL) -> Dict:
    """The verdict schema, from plain host-side counts (the analytic
    epaxos/rabia models and the runtime drivers build these directly)."""
    viol = {k: int(v) for k, v in (violations or {}).items() if int(v)}
    return {"ok": not viol, "violations": viol,
            "gauges": dict(gauges or {}), "level": level}


def verdict(result: Dict) -> Optional[Dict]:
    """Fold one collected sweep point into a verdict dict
    ``{"ok", "violations", "gauges", "level"}`` — or None when the point
    was produced with the monitor off. Accepts both scan results (a
    ``"mon"`` subtree of device arrays) and analytic/host results (a
    ready-made ``"monitor"`` dict)."""
    if "monitor" in result:
        return result["monitor"]
    mon = result.get("mon")
    if mon is None:
        return None
    viol: Dict[str, int] = {}
    level = MonitorLevel.GAUGES
    if "viol" in mon:
        level = MonitorLevel.FULL
        counts = np.asarray(mon["viol"])
        viol = {name: int(counts[i]) for i, name in enumerate(VIOLATIONS)
                if counts[i]}
    gauges = {
        "ring_occ_max": float(mon["ring_occ_max"]),
        "ring_occ_mean": float(mon["ring_occ_mean"]),
        "dropped_sends": int(np.sum(np.asarray(mon["dropped_sends"]))),
        "inflight_hwm": [round(float(x), 3)
                         for x in np.asarray(mon["inflight_hwm"])],
        "starved_max": [int(x) for x in np.asarray(mon["starved_max"])],
    }
    if "stall_max" in mon:
        gauges["stall_max_ticks"] = int(mon["stall_max"])
    return {"ok": not viol, "violations": viol, "gauges": gauges,
            "level": level}


def merge_verdicts(verdicts: List[Optional[Dict]]) -> Optional[Dict]:
    """Suite-level aggregate over per-point verdicts (None entries — e.g.
    non-sweep suites — are skipped)."""
    vs = [v for v in verdicts if v]
    if not vs:
        return None
    viol: Dict[str, int] = {}
    for v in vs:
        for k, c in v.get("violations", {}).items():
            viol[k] = viol.get(k, 0) + int(c)
    return {"ok": not viol, "violations": viol, "points": len(vs),
            "level": vs[0].get("level", MonitorLevel.FULL)}


def format_verdict(v: Optional[Dict]) -> str:
    """One-line rendering for benchmark summary lines."""
    if v is None:
        return "monitor off"
    if v.get("ok"):
        pts = v.get("points")
        return f"monitor OK ({pts} pts)" if pts else "monitor OK"
    parts = " ".join(f"{k}={c}" for k, c in sorted(
        v.get("violations", {}).items()))
    return f"monitor VIOLATIONS: {parts}"


def health_table(result: Dict) -> str:
    """Verdict + per-replica gauge table for one sweep point
    (benchmarks/inspect.py --health)."""
    v = verdict(result)
    if v is None:
        return ("(no health data: run with monitor_level='gauges' or "
                "'full')")
    lines = [f"health: {format_verdict(v)}  [level={v.get('level')}]"]
    g = v.get("gauges", {})
    scalars = {k: val for k, val in g.items()
               if not isinstance(val, (list, tuple))}
    if scalars:
        lines.append("  " + "  ".join(
            f"{k}={val:.4g}" if isinstance(val, float) else f"{k}={val}"
            for k, val in sorted(scalars.items())))
    vectors = {k: val for k, val in g.items()
               if isinstance(val, (list, tuple))}
    if vectors:
        n = max(len(val) for val in vectors.values())
        head = "  {:<16}".format("replica") + "".join(
            f"{i:>10}" for i in range(n))
        lines.append(head)
        for k, val in sorted(vectors.items()):
            lines.append("  {:<16}".format(k) + "".join(
                f"{x:>10.3g}" if isinstance(x, float) else f"{x:>10}"
                for x in val))
    return "\n".join(lines)


def check_cvc_trace(cvc: np.ndarray,
                    alive: Optional[np.ndarray] = None) -> Dict[str, int]:
    """Host-side re-check of a committed-VC trace ``[T, n, n]`` (the
    sporades ``cvc_all`` output): counts ticks violating agreement
    (pairwise comparability of alive replicas' committed rows) and prefix
    monotonicity. Used by the seeded-violation tests to show a mutated
    committed slot trips exactly the right monitor."""
    cvc = np.asarray(cvc)
    T, n, _ = cvc.shape
    if alive is None:
        alive = np.ones((T, n), bool)
    out = {"agreement": 0, "prefix": 0}
    ge = np.all(cvc[:, :, None, :] >= cvc[:, None, :, :], axis=-1)
    both = alive[:, :, None] & alive[:, None, :]
    out["agreement"] = int(np.sum(np.any(both & ~(ge | np.swapaxes(
        ge, 1, 2)), axis=(1, 2))))
    out["prefix"] = int(np.sum(np.any(cvc[1:] < cvc[:-1], axis=(1, 2))))
    return out


class HostMonitor:
    """Host-side twin of the device monitor for the pure-python runtime
    drivers (runtime/*_rt.py): the same invariant taxonomy over explicit
    commit/completion observations instead of scanned state."""

    def __init__(self, n: int):
        self.n = n
        self.violations: Dict[str, int] = {}
        self._view = np.full((n,), -1, np.int64)       # last (view) seen
        self._cut: List[Optional[np.ndarray]] = [None] * n
        self._slot: Dict[tuple, np.ndarray] = {}       # (view, round) -> cut
        self._done = np.zeros((n,), np.int64)          # completion rounds

    def _flag(self, name: str) -> None:
        assert name in VIOLATIONS, name
        self.violations[name] = self.violations.get(name, 0) + 1

    def observe_commit(self, who: int, view: int, rnd: int, cut) -> None:
        """One actor commits ``cut`` (a length-n committed vector) at
        (view, round)."""
        cut = np.asarray(cut)
        if view < self._view[who]:
            self._flag("view_monotone")
        self._view[who] = max(self._view[who], view)
        prev = self._cut[who]
        if prev is not None and np.any(cut < prev):
            self._flag("prefix")
        key = (int(view), int(rnd))
        if key in self._slot:
            if not np.array_equal(self._slot[key], cut):
                self._flag("commit_once")
        else:
            self._slot[key] = cut.copy()
        for other, oc in enumerate(self._cut):
            if other == who or oc is None:
                continue
            if not (np.all(cut >= oc) or np.all(cut <= oc)):
                self._flag("agreement")
        self._cut[who] = np.maximum(cut, prev) if prev is not None else cut

    def observe_completion(self, who: int, rnd: int) -> None:
        """One dissemination pod completes round ``rnd``: completions are
        strictly in round order and never repeat."""
        last = int(self._done[who])
        if rnd <= last:
            self._flag("commit_once")
        elif rnd != last + 1:
            self._flag("prefix")
        self._done[who] = max(last, rnd)

    def verdict(self) -> Dict:
        return host_verdict(self.violations,
                            gauges={"commits": len(self._slot),
                                    "completions": int(self._done.sum())})
