"""Mixture-of-Experts MLP: top-k token-choice routing with capacity-based
grouped dispatch (Mesh-TF style — dense one-hot einsums, TPU friendly),
optional parallel dense residual (arctic).

Experts are sharded over the ``model`` mesh axis (EP); dispatch/combine
einsums lower to all-to-alls under SPMD.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_mlp, swiglu


def init_moe(cfg: ModelConfig, key):
    m = cfg.moe
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    p = {
        "router": jax.random.normal(k1, (d, e)) * d ** -0.5,
        "w_gate": jax.random.normal(k2, (e, d, f)) * d ** -0.5,
        "w_up": jax.random.normal(k3, (e, d, f)) * d ** -0.5,
        "w_down": jax.random.normal(k4, (e, f, d)) * f ** -0.5,
    }
    if m.dense_residual:
        p["dense"] = init_mlp(cfg, k5, m.dense_d_ff)
    return p


def _capacity(group_size: int, n_experts: int, top_k: int, factor: float) -> int:
    # lint: allow(traced-purity): static expert-capacity math on Python
    # ints at trace time — shapes, not traced values
    c = int(group_size * top_k / n_experts * factor)
    return max(4, -(-c // 4) * 4)      # round up to multiple of 4


def moe_mlp(p, x, *, cfg: ModelConfig, group_size: int = 1024,
            ep_axis=None, tok_axes=()) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,D] -> (y, aux_loss). Tokens are processed in groups so the
    dispatch one-hots stay small ([G, S_g, E, C]). ``ep_axis`` switches on
    explicit expert parallelism over that mesh axis: dispatch is computed
    *locally* (groups sharded over ``tok_axes``), then a single resharding
    (g-sharded -> e-sharded) lowers to the EP all-to-all."""
    m = cfg.moe
    b, s, d = x.shape
    n_tok = b * s
    g_sz = min(group_size, n_tok)
    assert n_tok % g_sz == 0, (n_tok, g_sz)
    xg = x.reshape(n_tok // g_sz, g_sz, d)                  # [G, Sg, D]
    cap = _capacity(g_sz, m.n_experts, m.top_k, m.capacity_factor)

    logits = jnp.einsum("gsd,de->gse", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, m.top_k)              # [G,Sg,K]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # position-in-expert via cumsum over (slot-major) one-hots
    onehot = jax.nn.one_hot(topi, m.n_experts, dtype=jnp.float32)  # [G,Sg,K,E]
    # flatten (token, k) slots in priority order: k-major so top-1 wins capacity
    slots = onehot.transpose(0, 2, 1, 3).reshape(xg.shape[0], -1, m.n_experts)
    pos_in_e = (jnp.cumsum(slots, axis=1) - slots)          # [G, K*Sg, E]
    pos_in_e = pos_in_e.reshape(xg.shape[0], m.top_k, g_sz, m.n_experts)
    pos_in_e = pos_in_e.transpose(0, 2, 1, 3)               # [G,Sg,K,E]
    in_cap = pos_in_e < cap
    keep = onehot * in_cap                                   # [G,Sg,K,E]
    pos = jnp.einsum("gske,gske->gsk", pos_in_e, keep)      # slot index
    cap_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32) \
        * keep.sum(-1, keepdims=True)                        # [G,Sg,K,C]
    # dispatch/combine tensors
    disp = jnp.einsum("gske,gskc->gsec", keep, cap_oh)      # [G,Sg,E,C] 0/1
    comb = jnp.einsum("gske,gskc,gsk->gsec", keep, cap_oh, topv)
    dt = x.dtype

    def _wsc(t, spec):
        from jax.sharding import PartitionSpec as P
        try:
            return jax.lax.with_sharding_constraint(t, P(*spec))
        except Exception:
            return t

    xe = jnp.einsum("gsd,gsec->gecd", xg, disp.astype(dt))  # [G,E,C,D]
    if ep_axis is not None:
        # 1) dispatch stays token-local (groups sharded over tok_axes)
        xe = _wsc(xe, (tok_axes or None, None, None, None))
        # 2) reshard g-sharded -> e-sharded: the EP all-to-all
        xe = _wsc(xe, (None, ep_axis, None, None))
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u, p["w_down"])
    if ep_axis is not None:
        ye = _wsc(ye, (None, ep_axis, None, None))
        # return all-to-all before the token-local combine
        ye = _wsc(ye, (tok_axes or None, None, None, None))
    y = jnp.einsum("gecd,gsec->gsd", ye, comb.astype(dt)).reshape(b, s, d)

    # aux losses: load-balance (Switch) + router z-loss
    me = probs.mean(axis=(0, 1))                            # [E]
    ce = onehot.sum(2).mean(axis=(0, 1))                    # fraction routed
    aux = m.aux_loss * m.n_experts * jnp.sum(me * ce)
    zl = m.router_z_loss * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    if m.dense_residual:
        y = y + swiglu(p["dense"], x)
    return y, (aux + zl).astype(jnp.float32)
