"""repro — Mandator & Sporades as a multi-pod JAX training/serving framework."""

__version__ = "1.0.0"
