"""Sporades for the training control plane: dual-mode step/cut commit.

Synchronous mode: the view's leader proposes the next cut (a Mandator
round-vector); every live controller votes; one round-trip commit — O(n)
control messages per training step.

Asynchronous mode: if the leader (or the fabric) stalls past the timeout,
controllers run the two-height fallback and the shared-seed common coin
(core/coin.py — the exact primitive from §3.2.1) elects whose cut commits;
training liveness survives any minority of stalled/dead pods, which is the
paper's DDoS/crash resilience transplanted to stragglers and pod failures.

Transport is pluggable (in-process here); the protocol state machine is the
one verified tick-level in core/sporades.py — this runtime trades the tick
simulator for a synchronous scheduler usable inside a training loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.coin import common_coin_flip
from repro.obs.monitor import HostMonitor
from repro.obs.trace import HostTrace


@dataclass
class CommitRecord:
    view: int
    round: int
    cut: np.ndarray
    mode: str                      # "sync" | "async"


@dataclass
class ControllerState:
    idx: int
    alive: bool = True
    straggling: bool = False       # responds after the deadline
    v_cur: int = 0
    r_cur: int = 0
    committed: List[CommitRecord] = field(default_factory=list)


class SporadesRuntime:
    """Step-commit driver. Each call to `commit_step(cuts)` is one consensus
    round over the controllers' proposed cuts."""

    def __init__(self, n_pods: int, seed: int = 0):
        self.n = n_pods
        self.f = (n_pods - 1) // 2
        self.seed = seed
        self.ctl = [ControllerState(i) for i in range(n_pods)]
        self.view = 0
        self.round = 0
        # flight recorder (host-side twin of repro.obs, same taxonomy)
        self.trace = HostTrace()
        # health monitor: every commit any controller applies is checked
        # for monotone views, prefix order, commit-once and agreement
        self.monitor = HostMonitor(n_pods)

    # ---- liveness predicates ----------------------------------------------
    def _responsive(self) -> List[int]:
        return [c.idx for c in self.ctl if c.alive and not c.straggling]

    def _live(self) -> List[int]:
        return [c.idx for c in self.ctl if c.alive]

    def leader(self, view: int) -> int:
        return view % self.n

    # ---- one commit round ---------------------------------------------------
    def commit_step(self, cuts: Dict[int, np.ndarray]
                    ) -> Optional[CommitRecord]:
        """cuts: proposed vector-clock cut per live controller. Returns the
        committed record, or None if even the fallback lacks a quorum."""
        resp = [i for i in self._responsive() if i in cuts]
        ldr = self.leader(self.view)
        # ---- synchronous path: leader proposes, all responsive vote -------
        if ldr in resp and len(resp) >= self.n - self.f:
            cut = cuts[ldr]
            rec = CommitRecord(self.view, self.round + 1, cut.copy(), "sync")
            self._apply(rec, resp)
            self.trace.record("commit", rec.round, who=ldr,
                              key=rec.view, total=len(resp))
            return rec
        # ---- timeout -> asynchronous fallback ------------------------------
        self.trace.record("mode_switch", self.round, who=ldr,
                          is_async=1, view=self.view)
        live = [i for i in self._live() if i in cuts]
        if len(live) < self.n - self.f:
            return None                                  # no quorum at all
        # two-height exchange happens among `live`; the common coin elects
        view = self.view + 1
        elected = int(common_coin_flip(view, self.n, self.seed))
        self.trace.record("leader_change", self.round, who=elected,
                          leader=elected, view=view)
        # the elected block commits iff its controller completed height 2 —
        # i.e. it is among the live quorum ("first n-f async-complete")
        if elected in live:
            cut = cuts[elected]
            rec = CommitRecord(view, self.round + 1, cut.copy(), "async")
            self.view = view + 1
            self.trace.record("view_change", rec.round,
                              view=self.view, round=rec.round)
            self.trace.record("mode_switch", rec.round, who=elected,
                              is_async=0, view=self.view)
            self._apply(rec, live)
            self.trace.record("commit", rec.round, who=elected,
                              key=rec.view, total=len(live))
            return rec
        # coin landed on a dead/straggling pod: adopt its height-1 block if
        # seen (Bfall) — here: no commit this round, advance the view
        self.view = view + 1
        self.round += 1
        self.trace.record("view_change", self.round, view=self.view,
                          round=self.round)
        return None

    def _apply(self, rec: CommitRecord, voters: List[int]) -> None:
        self.round = rec.round
        for i in voters:
            c = self.ctl[i]
            c.v_cur = rec.view
            c.r_cur = rec.round
            c.committed.append(rec)
            self.monitor.observe_commit(i, rec.view, rec.round, rec.cut)

    # ---- failure injection ---------------------------------------------------
    def crash(self, pod: int) -> None:
        self.ctl[pod].alive = False
        self.trace.record("crash", self.round, who=pod,
                          view=self.view, round=self.round)

    def recover(self, pod: int) -> None:
        self.ctl[pod].alive = True
        self.trace.record("recover", self.round, who=pod,
                          view=self.view, round=self.round)

    def set_straggler(self, pod: int, straggling: bool = True) -> None:
        self.ctl[pod].straggling = straggling
