"""R4 drop-mask: Send constructed, committed without the drop mask."""


class Send:
    def __init__(self, dst, payload):
        self.dst = dst
        self.payload = payload


def ring_commit(ring, sends, drop=None):
    return ring, sends, drop


def relay(ring, inbox):
    msgs = [Send(1, m) for m in inbox]
    return ring_commit(ring, msgs)  # expect: R4
