"""Tracelint: static analysis proving the one-clean-compiled-program-
per-protocol invariants before runtime.

Two layers, one CLI (``python -m repro.analysis``):

  * ``repro.analysis.lint``      — stdlib-``ast`` repo lint (rules R1–R5,
    call-graph aware); runs without jax installed.
  * ``repro.analysis.hlo_lint``  — HLO program auditor over each
    protocol's canonical compiled program (rules H1–H4); needs jax and
    benefits from a warm persistent compile cache.

Import surface kept jax-free: ``hlo_lint`` is imported lazily by the
CLI only when ``--hlo`` is requested.
"""
from repro.analysis.findings import (Finding, Report, RULE_KEYS,
                                     format_table, load_baseline)
from repro.analysis.lint import ALL_RULES, run_lint

__all__ = ["Finding", "Report", "RULE_KEYS", "ALL_RULES",
           "run_lint", "format_table", "load_baseline"]
