"""WAN network environment: per-pair delays, NIC egress serialization, and
scenario-driven adversities (crash intervals, partitions, regional outages,
gray failures, the §5.5 targeted-minority DDoS, bandwidth throttles).

``build_env`` is fully array-native: every leaf of the returned dict is a
fixed-shape ``jnp`` array (no Python scalars), so environments built from
different scenarios can be stacked leaf-wise (``stack_envs``) and the whole
tick loop vmapped over the stacked axis by the batched experiment engine
(core/experiment.py).

Adverse conditions come in as *windowed tables* compiled from a declarative
``repro.scenarios.Scenario`` (see scenarios/compile.py): the run is cut
into W windows over which everything is constant, and the env carries
``win_of_tick [n_ticks]`` plus per-window ``alive_tab [W, n]``,
``drop_tab [W, n, n]``, ``delay_tab [W, n, n]`` (extra ticks), and
``nic_tab [W, n]`` (egress scale). Pass ``n_windows`` to pad the tables to
a common width before stacking; padding rows are never read because
``win_of_tick`` only indexes real windows.

``FaultSchedule`` is the seed-era fault model, kept as a thin compatibility
shim: it compiles to an equivalent Scenario (scenarios/compile.py), with
bitwise-identical tables pinned by tests/test_scenarios.py.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.smr import SMRConfig


@dataclass(frozen=True)
class FaultSchedule:
    """DEPRECATED shim over repro.scenarios (kept so seed-era callers keep
    their exact semantics; the fig 6-9 benchmarks now pass Scenarios).

    crash_time_s[i] — replica i stops at that time (inf = never).
    ddos: if enabled, every ``repick_s`` seconds a random minority set is
    attacked; their links gain ``attack_delay_ms`` each way."""
    crash_time_s: Optional[np.ndarray] = None
    ddos: bool = False
    ddos_attack_delay_ms: float = 800.0
    ddos_repick_s: float = 2.0
    ddos_seed: int = 7

    def __post_init__(self):
        warnings.warn(
            "netsim.FaultSchedule is deprecated; pass a "
            "repro.scenarios.Scenario (see scenarios.from_fault_schedule "
            "for the exact-equivalent compilation)",
            # 3, not 2: __post_init__ is called by the generated __init__,
            # so 2 would attribute the warning to dataclass-generated code
            DeprecationWarning, stacklevel=3)


def sim_ticks(cfg: SMRConfig) -> int:
    """Number of simulator ticks — static (known at trace time)."""
    return int(cfg.sim_seconds * 1000 / cfg.tick_ms)


def env_windows(cfg: SMRConfig, faults) -> int:
    """Windowed-table rows this scenario (or FaultSchedule) lowers to —
    used to pick a common pad width before stacking envs."""
    from repro import scenarios
    return scenarios.compile.n_windows(cfg, scenarios.as_scenario(faults))


def build_env(cfg: SMRConfig, faults=None,
              n_windows: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    """faults: a repro.scenarios.Scenario, a FaultSchedule (compat shim),
    or None (fault-free baseline)."""
    from repro import scenarios
    n = cfg.n_replicas
    tab = scenarios.lower(cfg, scenarios.as_scenario(faults),
                          pad_windows=n_windows)
    # Channels cap a message's total delay at delay_horizon_ticks - 1
    # (channel.send clips); NIC backlog beyond the horizon is delivered at
    # the horizon by design, but the *static* link + scenario delay
    # exceeding it is a misconfiguration that would silently distort every
    # message.
    static_delay = (np.max(cfg.delays_ms()) / cfg.tick_ms
                    + float(np.max(tab["extra_delay"], initial=0.0)))
    if static_delay >= cfg.delay_horizon_ticks:
        raise ValueError(
            f"link + scenario delay ({static_delay:.0f} ticks) exceeds "
            f"delay_horizon_ticks={cfg.delay_horizon_ticks}; raise the "
            "horizon in SMRConfig")
    return {
        "delays": jnp.asarray(cfg.delays_ms() / cfg.tick_ms),  # [n,n] ticks
        "win_of_tick": jnp.asarray(tab["win_of_tick"]),        # [n_ticks]
        "alive_tab": jnp.asarray(tab["alive"]),                # [W,n]
        "drop_tab": jnp.asarray(tab["drop"]),                  # [W,n,n]
        "delay_tab": jnp.asarray(tab["extra_delay"]),          # [W,n,n]
        "nic_tab": jnp.asarray(tab["nic_scale"]),              # [W,n]
        "bytes_per_tick": jnp.float32(
            cfg.nic_gbps * 1e9 / 8.0 * cfg.tick_ms / 1000.0),
        "cpu_req_per_tick": jnp.float32(
            cfg.tick_ms * 1000.0 / cfg.cpu_us_per_request),
    }


def stack_envs(envs: Sequence[Dict[str, jnp.ndarray]]) -> Dict[str, jnp.ndarray]:
    """Stack envs leaf-wise into a batched env (leading axis = variant).
    All envs must come from the same cfg and a common ``n_windows``."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *envs)


def _win(env, t) -> jax.Array:
    """Window row for tick t (scalar int32)."""
    return env["win_of_tick"][t]


def alive(env, t) -> jax.Array:
    """[n] bool — replica is up in tick t's window."""
    return env["alive_tab"][_win(env, t)]


def link_delay(env, t) -> jax.Array:
    """[n, n] delay in ticks including scenario extra delay (DDoS, outage
    turbulence, gray jitter)."""
    return env["delays"] + env["delay_tab"][_win(env, t)]


def link_drop(env, t) -> jax.Array:
    """[n, n] bool — links the scenario cuts this tick (partitions, gray
    loss). Feed to channel.send's drop mask."""
    return env["drop_tab"][_win(env, t)]


def nic_rate(env, t) -> jax.Array:
    """[n] effective egress bytes/tick per sender (throttle-scaled)."""
    return env["bytes_per_tick"] * env["nic_tab"][_win(env, t)]


def egress_delay(busy: jax.Array, t: jax.Array, bytes_out: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """NIC serialization. busy: [n] abs tick when NIC frees; bytes_out: [n,n]
    bytes sent this tick (serialized in receiver order). Returns
    (new_busy [n], extra_delay_ticks [n,n])."""
    # cumulative serialization time per receiver j (order: j ascending)
    # NOTE: the effective nic_rate is folded in by the caller.
    cum = jnp.cumsum(bytes_out, axis=1)
    start = jnp.maximum(busy, t.astype(jnp.float32))[:, None]
    finish = start + cum
    new_busy = start[:, 0] + cum[:, -1]
    return new_busy, finish - t.astype(jnp.float32)
