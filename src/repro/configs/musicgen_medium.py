"""musicgen-medium — decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Audio frontend is a STUB: input_specs() supplies precomputed EnCodec frame
embeddings (batch, seq, d_model) in place of the 4-codebook delay-pattern
embedding sum; the head predicts over the 2048-entry codebook vocab.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab=2048, embed_inputs=False,
    notes="MHA (kv=24); frame-embedding inputs (stub frontend)",
)
