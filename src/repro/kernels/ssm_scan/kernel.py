"""Selective-scan (Mamba) Pallas-TPU kernel.

The CUDA original keeps the [Di, N] state in shared memory and fuses the
whole recurrence; the TPU adaptation tiles Di across the grid and keeps a
[bd, N] fp32 state in VMEM scratch, streaming S in chunks via BlockSpecs.
Grid: (batch, Di/bd, S/chunk) — the S dim is sequential so the state scratch
carries across chunks. Per time step the update is VPU element-wise work on
[bd, N]; no [B, S, Di, N] tensor ever exists in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, o_ref, h_ref, *,
                chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a_mat = a_ref[...].astype(jnp.float32)              # [bd, N]
    d_vec = d_ref[...].astype(jnp.float32)              # [1, bd]

    def step(t, _):
        xt = x_ref[0, t, :].astype(jnp.float32)         # [bd]
        dtt = dt_ref[0, t, :].astype(jnp.float32)       # [bd]
        bt = b_ref[0, t, :].astype(jnp.float32)         # [N]
        ct = c_ref[0, t, :].astype(jnp.float32)         # [N]
        a = jnp.exp(dtt[:, None] * a_mat)               # [bd, N]
        h = a * h_ref[...] + (dtt * xt)[:, None] * bt[None, :]
        h_ref[...] = h
        y = jnp.sum(h * ct[None, :], axis=1) + d_vec[0] * xt
        o_ref[0, t, :] = y.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


def ssm_scan_pallas(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
                    A: jax.Array, D: jax.Array, *, bd: int = 256,
                    chunk: int = 128, interpret: bool = False) -> jax.Array:
    """x, dt: [Bt, S, Di]; B, C: [Bt, S, N]; A: [Di, N]; D: [Di]."""
    bsz, s, di = x.shape
    n = A.shape[1]
    bd = min(bd, di)
    chunk = min(chunk, s)
    assert di % bd == 0 and s % chunk == 0, (x.shape, bd, chunk)
    grid = (bsz, di // bd, s // chunk)
    kernel = functools.partial(_ssm_kernel, chunk=chunk)
    xd_spec = pl.BlockSpec((1, chunk, bd), lambda ib, id_, ic: (ib, ic, id_))
    bc_spec = pl.BlockSpec((1, chunk, n), lambda ib, id_, ic: (ib, ic, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            xd_spec, xd_spec, bc_spec, bc_spec,
            pl.BlockSpec((bd, n), lambda ib, id_, ic: (id_, 0)),
            pl.BlockSpec((1, bd), lambda ib, id_, ic: (0, id_)),
        ],
        out_specs=xd_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, s, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, B, C, A, D.reshape(1, di))
