"""Pure-jnp oracle for the fused channel-ring commit.

One simulator tick's worth of channel traffic against the packed ring
``buf [D, n, n, K]`` (all of a protocol's channels concatenated along the
field axis, each channel's flag field right after its payload — see
core/channel.RingSpec):

  1. slot-clear: slot ``t % D`` (the slot the tick just delivered) is reset
     to the per-field fill vector;
  2. ONE scatter-max over every max-merged payload field and every flag
     field of the tick's sends;
  3. ONE scatter-add over the additive payload fields (request counters).

Sends can never land in slot ``t % D`` (delay is clipped to ``[1, D-1]``
upstream), so the clear commutes with the scatters; duplicate scatter
indices (two sends on the same channel colliding in one slot) merge by max
exactly like sequential per-channel ``.at[].max`` calls did, and additive
channels send once per tick so index order cannot perturb float addition.

This is the CPU default and the parity oracle for the Pallas kernel
(kernel.py); tests/test_kernels.py pins interpret-mode equivalence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ring_commit_ref(buf: jax.Array, t: jax.Array, fill: jax.Array,
                    slots_max: jax.Array, fidx_max: jax.Array,
                    vals_max: jax.Array,
                    slots_add: jax.Array | None = None,
                    fidx_add: jax.Array | None = None,
                    vals_add: jax.Array | None = None) -> jax.Array:
    """buf: [D, n, n, K]; fill: [K] per-field clear value.
    slots_*: [n, n, F] target ring slot per scattered field;
    fidx_*: [F] static field index into K; vals_*: [n, n, F] merged values
    (masked-out entries already hold the merge-neutral fill)."""
    d, n = buf.shape[0], buf.shape[1]
    buf = buf.at[t % d].set(fill)                                # slot-clear
    ii = jnp.arange(n)[:, None, None]
    jj = jnp.arange(n)[None, :, None]
    buf = buf.at[slots_max, ii, jj, fidx_max[None, None, :]].max(vals_max)
    if fidx_add is not None:
        buf = buf.at[slots_add, ii, jj, fidx_add[None, None, :]].add(vals_add)
    return buf
