"""Production mesh factory. A FUNCTION (not a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = one v5e pod (256 chips); multi-pod adds a pure-DP 'pod' axis
    (2 pods = 512 chips). Requires enough (placeholder) devices — see
    launch/dryrun.py for the XLA_FLAGS bootstrap."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for tests (requires >= n_data*n_model host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
