"""§Roofline report: per (arch x shape) terms from the dry-run artifacts.

Reads benchmarks/artifacts/dryrun/*.json (produced by repro.launch.dryrun),
emits the single-pod roofline table (+ the multi-pod compile check) as
markdown + CSV rows. Hardware constants: TPU v5e — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI (distributed/hlo_analysis.py).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Tuple

ART = Path(__file__).resolve().parent / "artifacts"
DRY = ART / "dryrun"

Row = Tuple[str, float, str]


def load(mesh: str) -> List[dict]:
    out = []
    for p in sorted(DRY.glob(f"*__{mesh}.json")):
        out.append(json.loads(p.read_text()))
    return out


def table(mesh: str = "single") -> str:
    recs = load(mesh)
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | MODEL_FLOPS/HLO | bound (ms) | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | skipped: full-attention (no sub-quadratic "
                         f"path) |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.1f} | "
            f"{r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} | "
            f"{r['dominant']} | {r['useful_flop_ratio']:.2f} | "
            f"{r['bound_s']*1e3:.1f} | |")
    return "\n".join(lines)


def rows(mesh: str = "single") -> List[Row]:
    out: List[Row] = []
    for r in load(mesh):
        if "skipped" in r:
            out.append((f"roofline/{r['arch']}/{r['shape']}/{mesh}", 0.0,
                        "skipped=1"))
            continue
        out.append((
            f"roofline/{r['arch']}/{r['shape']}/{mesh}",
            r["bound_s"] * 1e6,
            f"dominant={r['dominant']};compute_ms={r['compute_s']*1e3:.2f};"
            f"memory_ms={r['memory_s']*1e3:.2f};"
            f"collective_ms={r['collective_s']*1e3:.2f};"
            f"useful={r['useful_flop_ratio']:.3f}"))
    return out


def channel_hlo_block(dmax: int = 256, ticks: int = 200) -> dict:
    """HLO cost + roofline terms of the packed-channel tick loop — the
    exact program the ``channel`` microbench times. Lowered and compiled
    in-process, the optimized HLO goes through the loop-aware
    ``distributed/hlo_analysis.module_cost`` walker; XLA's own flat
    ``cost_analysis()`` rides along as a cross-check (it counts the scan
    body once, so its flops read ~``ticks``x low by design).
    benchmarks/run.py drops this block into the channel suite's
    BENCH_core.json entry."""
    import jax

    from benchmarks.bench_kernels import packed_loop_fn
    from repro.distributed import hlo_analysis as ha

    compiled = jax.jit(packed_loop_fn(dmax=dmax, ticks=ticks)
                       ).lower().compile()
    cost = ha.module_cost(compiled.as_text())
    terms = ha.roofline_terms(cost["flops"], cost["bytes"],
                              cost["collective_bytes"])
    block = {
        "dmax": dmax, "ticks": ticks,
        "flops": float(cost["flops"]),
        "hbm_bytes": float(cost["bytes"]),
        "collective_bytes": float(cost["collective_bytes"]),
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "dominant": terms["dominant"],
        "bound_s": terms["bound_s"],
    }
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        block["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    except Exception:  # noqa: BLE001 — backend-dependent API, optional
        pass
    return block


def sweep_hlo_block(sim_seconds: float = 0.25,
                    protocol: str = "mandator-sporades") -> dict:
    """Where does the packed ring kernel sit now that run time matters?
    Lower the canonical single-lane sweep program (the unit of work every
    grid point executes, sharded or not) and attribute its HBM traffic by
    opcode with the loop-aware ``distributed/hlo_analysis.opcode_cost``
    walker. The packed channel ring shows up as the
    ``dynamic-update-slice`` scatter; its byte share is the headline
    number. benchmarks/run.py drops this block into the scaling suite's
    BENCH_core.json entry."""
    from functools import partial

    import jax

    from repro.configs.smr import SMRConfig
    from repro.core import experiment
    from repro.distributed import hlo_analysis as ha

    cfg = SMRConfig(sim_seconds=sim_seconds)
    spec = experiment.SweepSpec(rates=(200_000.0,))
    _, cfg, mode, env_b, wl_b, rate_b, seed_b, sig = experiment._lower(
        cfg, spec)
    fn = partial(experiment._sweep_body, protocol, cfg, mode)
    compiled = jax.jit(fn).lower(env_b, wl_b, rate_b, seed_b).compile()
    hlo = compiled.as_text()
    cost = ha.module_cost(hlo)
    ops = ha.opcode_cost(hlo)
    total = sum(d["bytes"] for d in ops.values()) or 1.0
    ring = ops.get("dynamic-update-slice", {"count": 0.0, "bytes": 0.0})
    top = sorted(ops.items(), key=lambda kv: -kv[1]["bytes"])[:8]
    return {
        "protocol": protocol, "signature": repr(sig),
        "sim_seconds": sim_seconds,
        "hbm_bytes": float(cost["bytes"]),
        "flops": float(cost["flops"]),
        "ring_scatter": {"count": float(ring["count"]),
                         "bytes": float(ring["bytes"]),
                         "byte_share": round(ring["bytes"] / total, 4)},
        "top_opcodes": [{"opcode": k, "count": float(d["count"]),
                         "bytes": float(d["bytes"]),
                         "byte_share": round(d["bytes"] / total, 4)}
                        for k, d in top],
    }


def summary(mesh: str = "single") -> dict:
    recs = [r for r in load(mesh) if "skipped" not in r]
    doms = {}
    for r in recs:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return {"cells": len(recs), "dominant_counts": doms,
            "mean_useful": sum(r["useful_flop_ratio"] for r in recs)
            / max(len(recs), 1)}


def main() -> None:
    for mesh in ("single", "multi"):
        recs = load(mesh)
        if not recs:
            continue
        md = table(mesh)
        (ART / f"roofline_{mesh}.md").write_text(md)
        print(f"# roofline ({mesh}): {len(recs)} cells -> "
              f"{ART}/roofline_{mesh}.md")
        print(json.dumps(summary(mesh), indent=1))


if __name__ == "__main__":
    main()
