"""Dispatch wrapper for the fused channel-ring commit.

Called from ``core/channel.ring_commit`` inside the (already-jitted) tick
scan, so there is no jit here — just backend selection and the reshaping
each backend wants. The pure-jnp oracle (ref.py) is the CPU default and
the correctness oracle; the Pallas kernel (kernel.py) is the TPU path and
runs in interpret mode for parity tests.

Backends: ``"jnp"`` (alias ``"ref"``), ``"pallas"``,
``"pallas-interpret"``, ``"auto"`` (pallas on TPU, jnp elsewhere).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.channel_ring.kernel import EntryLayout, ring_commit_tpu
from repro.kernels.channel_ring.ref import ring_commit_ref

BACKENDS = ("auto", "jnp", "ref", "pallas", "pallas-interpret")

# per-tick send entry, already mask-merged: (slot [n,n] int32,
# vals [n,n,w] float32 with merge-neutral at masked-out links,
# flag [n,n] float32 1.0/0.0)
Entry = Tuple[jax.Array, jax.Array, jax.Array]


def resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"unknown channel backend {backend!r}; "
                         f"one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return "jnp" if backend == "ref" else backend


def _scatter_args(entries: Sequence[Entry], layout: Sequence[EntryLayout]):
    """Concatenate the per-entry contributions along the field axis into
    the oracle's flat (slots, field-index, values) triples — max group
    (each max-merged send's contiguous payload+flag fields, plus every
    additive send's flag) and add group (additive payloads)."""
    sm, fm, vm = [], [], []
    sa, fa, va = [], [], []
    for (slot, vals, flag), (off, w, flag_off, additive) in zip(entries,
                                                                layout):
        if additive:
            sa.append(jnp.broadcast_to(slot[..., None], vals.shape))
            # lint: allow(traced-purity): field indices come from the
            # static EntryLayout — trace-time constants, not host data
            fa.append(np.arange(off, off + w))
            va.append(vals)
            sm.append(slot[..., None])
            # lint: allow(traced-purity): static EntryLayout flag index
            fm.append(np.array([flag_off]))
            vm.append(flag[..., None])
        else:
            # payload + flag are contiguous: one [n, n, w+1] block
            sm.append(jnp.broadcast_to(slot[..., None],
                                       slot.shape + (w + 1,)))
            # lint: allow(traced-purity): static EntryLayout field span
            fm.append(np.arange(off, off + w + 1))
            vm.append(jnp.concatenate([vals, flag[..., None]], axis=-1))
    cat = lambda xs: jnp.concatenate(xs, axis=-1)  # noqa: E731
    # lint: allow(traced-purity): concatenating the static index vectors
    # stays host-side; only jnp.asarray crosses to the device
    out = (cat(sm), jnp.asarray(np.concatenate(fm), jnp.int32), cat(vm))
    if sa:
        # lint: allow(traced-purity): static index vector (see above)
        return out + (cat(sa), jnp.asarray(np.concatenate(fa), jnp.int32),
                      cat(va))
    return out + (None, None, None)


def ring_commit(buf: jax.Array, t: jax.Array, fill: jax.Array,
                entries: Sequence[Entry], layout: Sequence[EntryLayout],
                backend: str = "auto") -> jax.Array:
    """Fused commit of one tick's sends: slot-clear of the delivered slot
    ``t % D`` + one scatter-max + one scatter-add (see ref.py)."""
    backend = resolve_backend(backend)
    if backend == "jnp":
        return ring_commit_ref(buf, t, fill,
                               *_scatter_args(entries, layout))
    interpret = (backend == "pallas-interpret"
                 or jax.default_backend() != "tpu")
    return ring_commit_tpu(buf, t, fill,
                           [e[0] for e in entries], [e[1] for e in entries],
                           [e[2] for e in entries], layout,
                           interpret=interpret)
