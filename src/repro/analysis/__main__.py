"""Tracelint CLI.

  PYTHONPATH=src python -m repro.analysis \\
      [--rules R1,R4,...] [--baseline analysis/baseline.json] \\
      [--json findings.json] [--hlo] [--hlo-history BENCH_history.jsonl]

Exit codes: 0 clean (active findings == 0), 1 findings, 2 internal
error. The AST layer (R1–R5) always runs and needs no jax; ``--hlo``
additionally lowers each scan protocol's canonical program and audits
the optimized HLO (H1–H4), appending the verdict to the benchmark
history ledger when ``--hlo-history`` names a path.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.findings import KEY_RULES, format_table, load_baseline
from repro.analysis.lint import ALL_RULES, run_lint


def _parse_rules(spec: str):
    rules = []
    for tok in spec.split(","):
        tok = tok.strip()
        if not tok:
            continue
        rule = tok.upper() if tok.upper() in ALL_RULES \
            else KEY_RULES.get(tok.lower())
        if rule not in ALL_RULES:
            sys.exit(f"unknown rule {tok!r}; valid: "
                     f"{', '.join(ALL_RULES)} (or their kebab keys)")
        rules.append(rule)
    return tuple(rules)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="tracelint: AST repo lint (R1-R5) + HLO program "
                    "auditor (H1-H4)")
    ap.add_argument("--root", default=None,
                    help="source tree to lint (default: the src/repro "
                         "this module was imported from)")
    ap.add_argument("--rules", default="",
                    help="comma list of AST rules to run, e.g. R1,R4 or "
                         "traced-purity,drop-mask (default: all)")
    ap.add_argument("--baseline", default=None,
                    help="JSON list of known findings that do not fail "
                         "the run (see findings.py)")
    ap.add_argument("--update-baseline", default=None,
                    help="write the current active findings as a new "
                         "baseline JSON and exit 0")
    ap.add_argument("--json", default=None,
                    help="write the full findings list (including "
                         "allowed/baselined) as JSON")
    ap.add_argument("--hlo", action="store_true",
                    help="also audit each protocol's canonical compiled "
                         "program (needs jax; cheap on a warm "
                         ".jax_cache)")
    ap.add_argument("--hlo-history", default=None,
                    help="append the HLO audit verdict to this "
                         "BENCH_history.jsonl ledger")
    ap.add_argument("--sim-seconds", type=float, default=2.0,
                    help="canonical program length for the HLO audit "
                         "(2.0 matches the --quick CI cache)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    root = Path(args.root) if args.root else Path(__file__).parents[1]
    rules = _parse_rules(args.rules) or None
    try:
        report = run_lint(root, rules=rules)
    except SyntaxError as e:
        print(f"error: cannot parse {e.filename}:{e.lineno}: {e.msg}",
              file=sys.stderr)
        return 2

    verdict = None
    if args.hlo:
        from repro.analysis import hlo_lint
        try:
            verdict = hlo_lint.audit(report=report,
                                     sim_seconds=args.sim_seconds)
        except Exception as e:  # noqa: BLE001 — exit 2, not a traceback
            print(f"error: HLO audit failed: {type(e).__name__}: {e}",
                  file=sys.stderr)
            return 2
        if not args.quiet:
            print(hlo_lint.format_verdict(verdict))

    if args.baseline:
        report.apply_baseline(load_baseline(args.baseline))
    if verdict is not None and args.hlo_history:
        from repro.analysis import hlo_lint
        counts = {"active": len(report.active)}
        counts.update(report.counts())
        hlo_lint.append_history(args.hlo_history, verdict,
                                analysis_counts=counts)
    if args.update_baseline:
        Path(args.update_baseline).write_text(
            json.dumps(report.baseline_json(), indent=1) + "\n")
        print(f"wrote baseline: {args.update_baseline} "
              f"({len(report.baseline_json())} findings)")
        return 0
    if args.json:
        Path(args.json).write_text(
            json.dumps(report.to_json(), indent=1) + "\n")

    active = report.active
    if not args.quiet:
        shown = [f for f in report.findings
                 if f.pragma != "none" or f.active]
        for line in format_table(shown):
            print(line)
        counts = report.counts()
        print(f"\n{len(active)} active finding(s)"
              + (f" ({', '.join(f'{r}={n}' for r, n in sorted(counts.items()))})"
                 if counts else "")
              + f"; {sum(1 for f in report.findings if f.pragma == 'allowed')}"
                " allowed by pragma, "
              + f"{sum(1 for f in report.findings if f.pragma == 'baselined')}"
                " baselined")
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
