"""Post-compile HLO analysis: a call-graph cost model + roofline terms.

Why not compiled.cost_analysis()? XLA's flat cost analysis counts each
while-loop *body once*, ignoring known_trip_count — a scan-over-layers
module under-reports FLOPs by ~n_layers x. We parse the optimized HLO
(compiled.as_text()) into its computation graph and walk it with loop
multipliers:

- FLOPs: dot ops (2 * prod(result_dims) * contracted_K) and matmul-like
  custom-calls, scaled by the product of enclosing known_trip_counts;
- HBM bytes: per top-level op, operand + result bytes at fusion boundaries
  (fusion internals stay in registers/VMEM — exactly the traffic model TPUs
  obey); parameter/tuple/gte/bitcast/constant ops are free;
- collective bytes: result sizes of all-gather / all-reduce / reduce-scatter
  / all-to-all / collective-permute, loop-scaled.

compiled.cost_analysis() is still recorded as a cross-check.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=()]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


# ---------------------------------------------------------------------------
# HLO module parsing (computations, ops, call graph with trip counts)
# ---------------------------------------------------------------------------

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
# result shape: a scalar/array shape, or a tuple (one nesting level deep —
# while-carry tuples in optimized HLO are flat, tokens may nest once)
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\((?:[^()]|\([^()]*\))*\)|[\w\[\],{}*/]+?)\s+"
    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_FREE_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota", "copy-start",
             "copy-done"}


def _base_opcode(oc: str) -> str:
    """Strip an async ``-start``/``-done`` SUFFIX (``str.rstrip`` strips a
    character set and would eat 'all-gather-start' down to 'all-gathe')."""
    for suf in ("-start", "-done"):
        if oc.endswith(suf):
            return oc[:-len(suf)]
    return oc


class _Op:
    __slots__ = ("name", "shape", "opcode", "rest", "line")

    def __init__(self, name, shape, opcode, rest, line):
        self.name, self.shape, self.opcode = name, shape, opcode
        self.rest, self.line = rest, line

    def callees(self) -> List[str]:
        """Computations this op invokes (while condition+body, call /
        fusion targets, conditional branches)."""
        out = re.findall(r"\b(?:calls|to_apply|condition|body)=%?"
                         r"([\w.\-]+)", self.line)
        for blk in re.findall(r"branch_computations=\{([^}]*)\}",
                              self.line):
            out.extend(nm.strip().lstrip("%") for nm in blk.split(",")
                       if nm.strip())
        return out


def _parse_module(hlo_text: str):
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        h = _COMP_HDR.match(line)
        if h:
            cur = h.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if m:
            comps[cur].append(_Op(m.group(1), m.group(2), m.group(3),
                                  m.group(4), line))
    return comps, entry


def _dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    return [(dt, [int(x) for x in dims.split(",") if x])
            for dt, dims in _SHAPE_RE.findall(shape_str)]


def _dot_flops(op: _Op, shapes: Dict[str, str]) -> float:
    res = _dims(op.shape)
    out_elems = sum(_prod(d) for _, d in res) or 1
    cm = _CONTRACT_RE.search(op.line)
    operands = [o for o in _OPERAND_RE.findall(op.rest)]
    k = 1
    if cm is not None and operands:
        lhs_shape = shapes.get(operands[0], "")
        ld = _dims(lhs_shape)
        if ld:
            dims = ld[0][1]
            for idx in (int(x) for x in cm.group(1).split(",") if x):
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * out_elems * k


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


def module_cost(hlo_text: str) -> Dict[str, object]:
    """Loop-aware flops / HBM bytes / collective bytes for the module."""
    comps, entry = _parse_module(hlo_text)
    # symbol table: op name -> result shape string (per module; names unique)
    shapes: Dict[str, str] = {}
    for ops in comps.values():
        for op in ops:
            shapes[op.name] = op.shape

    coll_acc = {k: {"count": 0.0, "bytes": 0.0} for k in COLLECTIVES}
    memo: Dict[str, Tuple[float, float, float]] = {}

    def comp_cost(name: str, mult: float) -> Tuple[float, float, float]:
        """(flops, bytes, coll_bytes) of one execution of computation."""
        if name in memo:
            f, b, c = memo[name]
            _acc_coll(name, mult)
            return f, b, c
        flops = byts = coll = 0.0
        for op in comps.get(name, ()):
            oc = op.opcode
            if oc in _FREE_OPS:
                continue
            if oc == "while":
                body = _COND_BODY_RE.search(op.line)
                trips = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trips = int(tm.group(1))
                if body:
                    f, b, c = comp_cost(body.group(1), mult * trips)
                    flops += f * trips
                    byts += b * trips
                    coll += c * trips
                continue
            if oc == "call":
                tgt = _CALLS_RE.search(op.line)
                if tgt:
                    f, b, c = comp_cost(tgt.group(1), mult)
                    flops += f
                    byts += b
                    coll += c
                continue
            if oc == "fusion":
                # fused bodies: count FLOPs (a dot may be fused) but not
                # bytes — internals never touch HBM; boundary counted below
                tgt = _CALLS_RE.search(op.line)
                if tgt:
                    f, _, c = comp_cost(tgt.group(1), mult)
                    flops += f
                    coll += c
            if oc == "conditional":
                for tgt in re.findall(r"branch_computations=\{([^}]*)\}",
                                      op.line):
                    for nm in tgt.replace("%", "").split(","):
                        nm = nm.strip()
                        if nm:
                            f, b, c = comp_cost(nm, mult)
                            flops += f
                            byts += b
                            coll += c
                continue
            if oc == "dot":
                flops += _dot_flops(op, shapes)
            if oc == "custom-call" and ("matmul" in op.line
                                        or "dot" in op.line.lower()):
                flops += _dot_flops(op, shapes)
            if oc == "convolution":
                # rare here; approximate as 2 * out_elems * K from window
                flops += 2.0 * sum(_prod(d) for _, d in _dims(op.shape))
            # HBM traffic at op boundary: operands + result. In-place slice
            # updates alias the big buffer — count only the moved slice.
            b_res = _shape_bytes(op.shape)
            op_sizes = [_shape_bytes(shapes[on])
                        for on in _OPERAND_RE.findall(op.rest)
                        if on in shapes]
            b_ops = sum(op_sizes)
            is_dus = ("dynamic-update-slice" in op.name
                      or oc == "dynamic-update-slice")
            is_ds = (not is_dus and ("dynamic-slice" in op.name
                                     or oc == "dynamic-slice"))
            if is_dus and op_sizes:
                moved = b_ops - max(op_sizes)
                byts += 2.0 * moved
                continue
            if is_ds:
                byts += 2.0 * b_res
                continue
            if oc in COLLECTIVES or _base_opcode(oc) in COLLECTIVES:
                kind = _base_opcode(oc)
                if kind in COLLECTIVES and not oc.endswith("-done"):
                    coll_acc[kind]["count"] += mult
                    coll_acc[kind]["bytes"] += b_res * mult
                    coll += b_res
            byts += b_res + b_ops
        memo[name] = (flops, byts, coll)
        return flops, byts, coll

    def _acc_coll(name: str, mult: float) -> None:
        for op in comps.get(name, ()):
            oc = op.opcode
            if oc == "while":
                body = _COND_BODY_RE.search(op.line)
                trips = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trips = int(tm.group(1))
                if body:
                    _acc_coll(body.group(1), mult * trips)
            elif oc in ("call", "fusion"):
                tgt = _CALLS_RE.search(op.line)
                if tgt:
                    _acc_coll(tgt.group(1), mult)
            kind = _base_opcode(oc)
            if kind in COLLECTIVES and not oc.endswith("-done"):
                coll_acc[kind]["count"] += mult
                coll_acc[kind]["bytes"] += _shape_bytes(op.shape) * mult

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collectives": coll_acc}
    # entry walk must also expand fusion-called computations? fusions are
    # element-fused bodies — internal traffic intentionally not counted.
    flops, byts, coll_entry = comp_cost(entry, 1.0)

    # while bodies reached only via comp_cost recursion; collectives were
    # accumulated there with multipliers.
    total_coll = sum(v["bytes"] for v in coll_acc.values())
    return {"flops": flops, "bytes": byts, "collective_bytes": total_coll,
            "collectives": coll_acc}


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes} (loop-aware)."""
    return module_cost(hlo_text)["collectives"]


def opcode_cost(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Loop-aware per-base-opcode {count, bytes} over the whole module —
    the attribution view of ``module_cost``'s HBM total: which opcode
    class (e.g. the packed ring's ``dynamic-update-slice`` scatter)
    carries the traffic. Bytes follow the same boundary model as
    ``module_cost`` (operands + result per top-level op; in-place slice
    updates count only the moved slice; fusion internals are free), so
    the per-opcode bytes sum to the same order as ``module_cost``'s
    total. Executions multiply by enclosing ``known_trip_count``s."""
    comps, entry = _parse_module(hlo_text)
    shapes: Dict[str, str] = {}
    for ops in comps.values():
        for op in ops:
            shapes[op.name] = op.shape
    acc: Dict[str, Dict[str, float]] = {}

    def walk(name: str, mult: float) -> None:
        for op in comps.get(name, ()):
            oc = op.opcode
            if oc == "while":
                trips = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trips = int(tm.group(1))
                body = _COND_BODY_RE.search(op.line)
                if body:
                    walk(body.group(1), mult * trips)
                continue
            if oc == "call":
                tgt = _CALLS_RE.search(op.line)
                if tgt:
                    walk(tgt.group(1), mult)
                continue
            if oc == "conditional":
                for blk in re.findall(r"branch_computations=\{([^}]*)\}",
                                      op.line):
                    for nm in blk.replace("%", "").split(","):
                        nm = nm.strip()
                        if nm:
                            walk(nm, mult)
                continue
            if oc in _FREE_OPS:
                continue
            b_res = _shape_bytes(op.shape)
            op_sizes = [_shape_bytes(shapes[on])
                        for on in _OPERAND_RE.findall(op.rest)
                        if on in shapes]
            is_dus = ("dynamic-update-slice" in op.name
                      or oc == "dynamic-update-slice")
            is_ds = (not is_dus and ("dynamic-slice" in op.name
                                     or oc == "dynamic-slice"))
            # in-place slice updates keep their identity through fusion
            # (XLA names the fusion after its root), so classify by the
            # effective op — the ring scatter stays visible as
            # dynamic-update-slice instead of vanishing into "fusion"
            if is_dus:
                base = "dynamic-update-slice"
            elif is_ds:
                base = "dynamic-slice"
            else:
                base = _base_opcode(oc)
            d = acc.setdefault(base, {"count": 0.0, "bytes": 0.0})
            d["count"] += mult
            if is_dus and op_sizes:
                d["bytes"] += 2.0 * (sum(op_sizes) - max(op_sizes)) * mult
            elif is_ds:
                d["bytes"] += 2.0 * b_res * mult
            else:
                d["bytes"] += (b_res + sum(op_sizes)) * mult

    if entry is not None:
        walk(entry, 1.0)
    return acc


# ---------------------------------------------------------------------------
# Program-audit queries (repro.analysis.hlo_lint): dtype census, while
# topology, host-transfer detection
# ---------------------------------------------------------------------------

# custom-call targets that round-trip through the host (python callbacks,
# host send/recv) — their presence inside the scan loop is the failure
# class the flight-recorder/monitor levels were designed to avoid
_HOST_CALL_MARKERS = ("callback", "host", "python", "py_func")
_HOST_TRANSFER_OPCODES = {"infeed", "outfeed", "send", "recv",
                          "send-done", "recv-done"}


def dtype_op_counts(hlo_text: str) -> Dict[str, int]:
    """Ops per result dtype across the module (tuple results count each
    element). The f64 audit asserts ``dtype_op_counts(...)['f64'] == 0``."""
    comps, _ = _parse_module(hlo_text)
    out: Dict[str, int] = {}
    for ops in comps.values():
        for op in ops:
            for dt, _dims in _SHAPE_RE.findall(op.shape):
                out[dt] = out.get(dt, 0) + 1
    return out


def _comp_reach(comps, roots, through_while: bool):
    """Computations reachable from ``roots`` via op callees; while
    condition/body edges are followed only when ``through_while``."""
    seen, stack = set(), list(roots)
    while stack:
        name = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        for op in comps[name]:
            if op.opcode == "while" and not through_while:
                continue
            stack.extend(c for c in op.callees() if c not in seen)
    return seen


def while_stats(hlo_text: str) -> List[Dict[str, object]]:
    """Every ``while`` op in the module: its computation, body/condition
    targets, ``known_trip_count``, and whether it is OUTER (reachable
    from ENTRY without crossing another while). A fused scan compiles to
    exactly one outer while; an unrolled or split scan does not."""
    comps, entry = _parse_module(hlo_text)
    outer_comps = _comp_reach(comps, [entry] if entry else [], False)
    out: List[Dict[str, object]] = []
    for cname, ops in comps.items():
        for op in ops:
            if op.opcode != "while":
                continue
            tm = _TRIP_RE.search(op.line)
            body = _COND_BODY_RE.search(op.line)
            out.append({
                "name": op.name,
                "comp": cname,
                "body": body.group(1) if body else None,
                "trip_count": int(tm.group(1)) if tm else None,
                "outer": cname in outer_comps,
            })
    return out


def loop_computations(hlo_text: str):
    """The set of computations that execute inside some while loop."""
    comps, _ = _parse_module(hlo_text)
    bodies = []
    for ops in comps.values():
        for op in ops:
            if op.opcode == "while":
                bodies.extend(op.callees())
    return _comp_reach(comps, bodies, True)


def host_transfer_ops(hlo_text: str) -> List[Dict[str, object]]:
    """Host round-trips in the module: infeed/outfeed/send/recv ops and
    custom-calls targeting python/host callbacks, each tagged with
    whether it sits inside a while loop (``in_loop``) — the audit asserts
    none do."""
    comps, _ = _parse_module(hlo_text)
    in_loop = loop_computations(hlo_text)
    out: List[Dict[str, object]] = []
    for cname, ops in comps.items():
        for op in ops:
            oc = op.opcode
            hit = oc in _HOST_TRANSFER_OPCODES
            if oc == "custom-call":
                m = re.search(r'custom_call_target="([^"]*)"', op.line)
                target = (m.group(1) if m else "").lower()
                hit = any(k in target for k in _HOST_CALL_MARKERS)
            if hit:
                out.append({"opcode": oc, "name": op.name, "comp": cname,
                            "in_loop": cname in in_loop})
    return out


def total_collective_bytes(hlo_text: str) -> float:
    return float(module_cost(hlo_text)["collective_bytes"])


# ---- roofline ---------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~ per chip usable)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float) -> Dict[str, float]:
    """The three §Roofline terms, in seconds (per device, per step)."""
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = coll_bytes_per_device / ICI_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", collective_s), key=lambda kv: kv[1])
    bound = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dom[0],
        "bound_s": bound,
        # fraction of the step spent at the dominant roofline — how close the
        # compiled program is to being purely roofline-limited
        "compute_fraction": compute_s / bound if bound > 0 else 0.0,
    }
