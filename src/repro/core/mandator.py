"""Mandator (Algorithm 1) — consensus-agnostic asynchronous request
dissemination, faithful to the paper:

- every replica runs its own chain of Mandator-batches,
- a batch is broadcast, voted, and *completed* once n-f <Mandator-vote>s
  arrive; the next batch (carrying lastCompletedRounds implicitly through
  its parent link) is only formed after completion (awaitingAcks gate),
- getClientRequests() returns the replica's lastCompletedRounds[] vector
  clock — the only thing the consensus layer ever orders.

Simulator mapping: <new-Mandator-batch> and <Mandator-vote> are monotone
payloads (round numbers), so channel merges are benign (channel.py).
Implementation §4 notes: child processes and selective-broadcast change
constants (hop count / memory), not the algorithm; we model the 1-child
configuration's bandwidth on the replica NIC directly (DESIGN.md §8).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.smr import SMRConfig
from repro.core import channel as ch
from repro.core import netsim, workload
from repro.obs import monitor as hmon
from repro.obs import trace as obs

def ring_spec() -> ch.RingSpec:
    """Packed delivery ring: both message types in one fused buffer."""
    return ch.RingSpec(
        ch.ChannelSpec("batch", 2),    # (round, lastCompleted)
        ch.ChannelSpec("vote", 1),
    )


def init_state(cfg: SMRConfig, n_ticks: int, closed: bool = False) -> Dict:
    n = cfg.n_replicas
    dmax = cfg.delay_horizon_ticks
    # flight-recorder state rides in the protocol dict; None (and absent
    # from the carry) at trace_level="off" so the untraced program is
    # structurally identical to the pre-recorder build
    tr = obs.init_trace(obs.DEFAULT_SPEC, cfg.trace_level, n,
                        cfg.trace_events)
    extra = {"tr": tr} if tr is not None else {}
    # health monitor per-tick IO gauges (repro.obs.monitor): absent at
    # monitor_level="off", same structural gating as the recorder
    if hmon.on(cfg.monitor_level):
        extra["mon_io"] = {"dropped": jnp.zeros((n,), jnp.int32)}
    return {
        **extra,
        "wl": workload.init_workload(cfg, n_ticks, closed=closed),
        "own_round": jnp.zeros((n,), jnp.int32),       # last completed round
        "formed_round": jnp.zeros((n,), jnp.int32),    # last formed round
        "lcr": jnp.zeros((n, n), jnp.int32),           # i's lastCompletedRounds
        "seen_round": jnp.zeros((n, n), jnp.int32),    # i's max batch seen from j
        "vote_max": jnp.zeros((n, n), jnp.int32),      # votes i received from j
        "ring": ch.make_ring(ring_spec(), dmax, n),
        "egress_busy": jnp.zeros((n,), jnp.float32),
    }


def tick(st: Dict, t: jax.Array, key: jax.Array, env: Dict, cfg: SMRConfig,
         rate_per_tick: jax.Array, wlt: Dict | None = None,
         mode: workload.WorkloadMode = workload.TRIVIAL_MODE) -> Dict:
    n = cfg.n_replicas
    f = (n - 1) // 2
    quorum = n - f
    alive = netsim.alive(env, t)
    delays = netsim.link_delay(env, t)
    drop = netsim.link_drop(env, t)
    st = dict(st)
    # one fused pop of slot t for every channel; sends buffer up and commit
    # as one fused scatter at the end of the tick (same-tick sends always
    # land at t+1 or later, so the reorder is exact — channel.py)
    spec = ring_spec()
    msgs = ch.ring_deliver(spec, st["ring"], t)
    sends = []

    # 1) client arrivals + cpu refill
    wl = workload.arrive(st["wl"], key, t, rate_per_tick, alive, wlt, mode)
    wl = workload.refill_cpu(wl, env["cpu_req_per_tick"])

    # 2) deliver <new-Mandator-batch>: update seen rounds + lcr, send votes
    bflags, bpayload = msgs["batch"]
    folded = ch.fold_state(
        jnp.stack([st["seen_round"], st["lcr"]], axis=-1).astype(jnp.float32),
        bflags, bpayload)
    seen = folded[..., 0].astype(jnp.int32)
    # batch carries its creator's lastCompletedRounds (parent link, line 15)
    lcr = folded[..., 1].astype(jnp.int32)
    # vote for every newly seen batch (line 16): cumulative vote = max round
    vote_mask = jnp.swapaxes(bflags, 0, 1) & alive[:, None]   # [voter, owner]
    vote_payload = seen.astype(jnp.float32)[..., None]        # [n, n, 1]
    sends.append(ch.Send("vote", vote_payload, delays.astype(jnp.int32),
                         vote_mask))

    # 3) deliver votes; in-order completion check (lines 17-19); with lanes,
    #    several rounds may complete back-to-back in one tick
    vflags, vpayload = msgs["vote"]
    vote_max = ch.fold_state(st["vote_max"].astype(jnp.float32)[..., None],
                             vflags, vpayload)[..., 0].astype(jnp.int32)
    own_round = st["own_round"]
    for _ in range(cfg.mandator_lanes):
        await_round = own_round + 1
        votes = jnp.sum(vote_max >= await_round[:, None], axis=1)
        done = (st["formed_round"] >= await_round) & (votes >= quorum)
        own_round = jnp.where(done, await_round, own_round)
    lcr = lcr.at[jnp.arange(n), jnp.arange(n)].set(own_round)

    # 4) form + broadcast next batch (lines 8-12); §4 child processes allow
    #    up to `mandator_lanes` outstanding batches per chain
    can_form = alive & (st["formed_round"] - own_round < cfg.mandator_lanes)
    wl, formed, count = workload.form_batches(
        wl, t, can_form, st["formed_round"] + 1, cfg.batch_mandator,
        cfg.max_batch_ms / cfg.tick_ms)
    formed_round = jnp.where(formed, st["formed_round"] + 1, st["formed_round"])
    # child processes serialize on their own NIC share; we model the replica
    # NIC as the shared egress (DESIGN.md §8)
    bytes_out = (count * cfg.request_bytes + 100.0)[:, None] * formed[:, None]
    bytes_out = jnp.broadcast_to(bytes_out, (n, n)) \
        / netsim.nic_rate(env, t)[:, None]
    busy, ser_delay = netsim.egress_delay(st["egress_busy"], t, bytes_out)
    busy = jnp.where(formed, busy, st["egress_busy"])
    total_delay = (delays + jnp.where(formed[:, None], ser_delay, 0.0)
                   ).astype(jnp.int32)
    bpay = jnp.stack([formed_round, own_round], axis=-1).astype(
        jnp.float32)[:, None, :] * jnp.ones((n, n, 1))
    sends.append(ch.Send("batch", bpay, total_delay,
                         formed[:, None] & jnp.ones((n, n), jnp.bool_)))

    ring = ch.ring_commit(spec, st["ring"], t, sends, drop=drop,
                          backend=cfg.channel_backend)

    # ---- flight recorder + monitor IO (absent => compiled out) ------------
    tr = st.get("tr")
    if tr is not None or "mon_io" in st:
        cut = jnp.sum(vote_mask & drop, axis=1) \
            + jnp.sum(formed[:, None] & drop, axis=1)
    if tr is not None:
        es = obs.DEFAULT_SPEC
        completed = own_round - st["own_round"]
        done = completed > 0
        tr = obs.record(es, tr, "batch_ack", done, t, a=own_round, b=quorum)
        tr = obs.record(es, tr, "batch_stable", done, t, a=own_round,
                        b=completed)
        tr = obs.record(es, tr, "batch_create", formed, t, a=formed_round,
                        b=count)
        tr = obs.record(es, tr, "batch_disseminate", formed, t,
                        a=formed_round, b=jnp.max(ser_delay, axis=1))
        tr = obs.record_env(es, tr, alive, t, a=own_round, b=formed_round,
                            dropped_links=cut)
        st["tr"] = tr
    if "mon_io" in st:
        st["mon_io"] = {"dropped": cut.astype(jnp.int32)}

    st.update(wl=wl, own_round=own_round, formed_round=formed_round, lcr=lcr,
              seen_round=seen, vote_max=vote_max, ring=ring,
              egress_busy=busy)
    return st


def get_client_requests(st: Dict) -> jax.Array:
    """lastCompletedRounds — the consensus payload (line 20-21). [n, n]."""
    return st["lcr"]
