"""Sharding policy: DP / FSDP / TP / EP / SP rules per (arch x shape).

Mesh axes: ("pod", "data", "model") multi-pod, ("data", "model") single pod.
- batch        -> ("pod", "data")   [DP; pod axis is pure DP -> clean elastic]
- weights      -> TP over "model" on head/ffn/expert/channel dims; FSDP over
                  "data" on the other big dim for >=20B-param archs (ZeRO-3)
- experts      -> EP over "model" (leading expert dim)
- KV cache     -> batch over "data", sequence over "model" (SP decode:
                  flash-decoding style partial-softmax combine, inserted by
                  SPMD from the sharding constraints)
- optimizer    -> same specs as params (ZeRO-1 falls out of FSDP+TP)

Every rule degrades to replication when a dim is not divisible by the axis
size (e.g. smollm's 9 heads), so every (arch x shape x mesh) cell compiles.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, param_count

FSDP_THRESHOLD = 20_000_000_000  # params; above this, shard weights over data


def _axis(mesh: Mesh, name: str) -> Optional[str]:
    return name if name in mesh.axis_names else None


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axis, tuple):
        k = 1
        for a in axis:
            k *= sizes[a]
    else:
        k = sizes[axis]
    return n % k == 0


def param_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh, *,
                fsdp: bool, stacked: bool, policy: str = "tp") -> P:
    """Sharding rule for one parameter leaf. `path` is the flattened key
    path; `stacked` leaves carry a leading repeats dim.

    Policies (hillclimbs — EXPERIMENTS.md §Perf):
      tp        — baseline TP(+FSDP) rules
      seqpar    — replicate backbone weights (small models); activations are
                  sequence-sharded via CallConfig.seq_axis
      tp_gqa    — as tp, but KV projections replicated (pairs with
                  CallConfig.gqa_expand_kv: head-aligned attention TP)
      ep_data   — as tp_gqa, but MoE experts sharded over the *data* axis
                  (EP via dispatch all-to-all; no per-step weight gathers)
    """
    lead = (None,) if stacked else ()
    dims = shape[1:] if stacked else shape
    model = _axis(mesh, "model")
    data = _axis(mesh, "data") if fsdp else None

    def ok(i, ax):  # divisibility guard
        return ax if _div(dims[i], mesh, ax) else None

    name = path.split("/")[-1]
    if policy == "seqpar":
        # replicate everything (incl. head: logits stay sequence-sharded,
        # the loss never gathers S or V)
        return P(*lead, *([None] * len(dims)))
    if "embed" in path or name == "head":
        if name == "embed":
            return P(*lead, ok(0, model), None)         # [V, D]
        return P(*lead, None, ok(1, model))             # [D, V]
    if name in ("final_norm", "norm1", "norm2", "cross_norm"):
        return P(*lead, None)
    if len(dims) == 3 and name in ("w_gate", "w_up", "w_down"):
        # MoE expert weights [E, D, F] / [E, F, D]
        if policy in ("ep_data", "ep_seq"):
            e_ax = ok(0, _axis(mesh, "data"))
            f_idx = 2 if name != "w_down" else 1
            spec = [e_ax, None, None]
            if _div(dims[f_idx], mesh, model):
                spec[f_idx] = model
            return P(*lead, *spec)
        e_ax = ok(0, model)
        f_ax = ok(1, data) if e_ax else ok(1, model)
        return P(*lead, e_ax, f_ax, None)
    if name == "router":
        return P(*lead, None, None)
    if policy == "ep_seq":
        # sequence-parallel backbone: dense weights FSDP over data only
        # (attention is sharded on S, so head dims stay whole)
        fs = _axis(mesh, "data")
        if len(dims) == 2:
            return P(*lead, ok(0, fs), None)
        if len(dims) == 1:
            return P(*lead, None)
    if policy in ("tp_gqa", "ep_data") and name in ("wk", "wv", "bk", "bv"):
        return P(*lead, *([None] * len(dims)))          # replicate KV proj
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_og",
                "w_i", "w_f", "w_z", "w_o"):
        return P(*lead, ok(0, data), ok(1, model))      # [D, out]
    if name in ("wo", "w_down", "w_out"):
        return P(*lead, ok(0, model), ok(1, data))      # [in, D]
    if name in ("bq", "bk", "bv", "conv_b", "dt_bias", "b_og", "b_i", "b_f",
                "b_z", "b_o", "D"):
        return P(*lead, ok(0, model))
    if name in ("w_bc", "w_dt", "A_log"):
        return P(*lead, ok(0, model), None)             # [Di, *]
    if name == "conv_w":
        return P(*lead, None, ok(1, model))             # [K, Di]
    if name.startswith("r_"):                            # sLSTM [H, dh, dh]
        return P(*lead, None, None, ok(2, model))
    if name in ("q_norm", "k_norm"):
        return P(*lead, None)
    return P(*lead, *([None] * len(dims)))


def _path_str(path) -> str:
    parts = []
    for pe in path:
        if hasattr(pe, "key"):
            parts.append(str(pe.key))
        elif hasattr(pe, "idx"):
            parts.append(str(pe.idx))
        else:
            parts.append(str(pe))
    return "/".join(parts)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape,
                    policy: str = "tp") -> Any:
    """Pytree of NamedSharding matching init_params structure (from
    jax.eval_shape)."""
    fsdp = param_count(cfg) >= FSDP_THRESHOLD

    def one(path, leaf):
        ps = _path_str(path)
        stacked = ps.startswith("blocks")
        spec = param_pspec(ps, leaf.shape, mesh, fsdp=fsdp, stacked=stacked,
                           policy=policy)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    batch_shape) -> Any:
    """Input batch: shard the leading batch dim over (pod, data)."""
    baxes = batch_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        b = leaf.shape[0]
        ax = baxes if (baxes and _div(b, mesh, baxes)) else (
            ("data",) if _div(b, mesh, "data" if "data" in mesh.axis_names
                              else None) else ())
        spec = P(ax if ax else None, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, batch_shape)


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    cache_shape) -> Any:
    """KV/recurrent cache: batch -> data axes, long dims -> model (SP)."""
    model = _axis(mesh, "model")
    baxes = batch_axes(mesh)

    def one(path, leaf):
        ps = _path_str(path)
        name = ps.split("/")[-1]
        dims = leaf.shape              # [R, B, ...]
        b = dims[1]
        b_ax = baxes if (baxes and _div(b, mesh, baxes)) else (
            ("data",) if _div(b, mesh, ("data",)) else None)
        rest: list = [None] * (len(dims) - 2)
        if name in ("k", "v"):
            # [R, B, S, Kh, Dh] — shard sequence (SP decode)
            s_ax = model
            if b_ax is None and _div(dims[2], mesh, ("data", "model")
                                     if "data" in mesh.axis_names else model):
                s_ax = tuple(a for a in ("data", "model")
                             if a in mesh.axis_names)
            if _div(dims[2], mesh, s_ax):
                rest[0] = s_ax
        elif name in ("conv", "h", "C", "n", "m", "c"):
            # recurrent state: shard the (largest) channel dim over model
            #   mamba: conv [R,B,K,Di]->Di@1, h [R,B,Di,N]->Di@0
            #   mlstm: C [R,B,H,dk,dv]->dk@1, n [R,B,H,dk]->dk@1, m: none
            #   slstm: c/n/h/m [R,B,Di]->Di@0
            if len(dims) == 3:
                ch_idx = 0
            elif name == "conv":
                ch_idx = 1
            elif name == "h":
                ch_idx = 0
            elif name in ("C", "n"):
                ch_idx = 1
            else:
                ch_idx = None
            if ch_idx is not None and ch_idx < len(rest) \
                    and _div(dims[2 + ch_idx], mesh, model):
                rest[ch_idx] = model
        spec = P(None, b_ax, *rest)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def constrain_activations(x: jax.Array, mesh: Optional[Mesh]) -> jax.Array:
    if mesh is None:
        return x
    baxes = batch_axes(mesh)
    if baxes and x.shape[0] % _prod_axes(mesh, baxes) == 0:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(baxes, *([None] * (x.ndim - 1)))))
    return x


def _prod_axes(mesh: Mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    k = 1
    for a in axes:
        k *= sizes[a]
    return k
