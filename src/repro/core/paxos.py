"""Multi-Paxos baseline (§5's monolithic leader-based protocol) and its
Mandator composition (Mandator-Paxos).

Plain mode: clients forward requests to the current leader; the leader runs
one consensus slot at a time (no pipelining, §5.2) carrying the request
batch *in* the accept message (the monolithic anti-pattern the paper
targets) — throughput is bound by batch/slot-RTT and the leader's NIC.

Mandator mode: the slot payload is the leader's lastCompletedRounds vector
clock (meta_bytes), committing every disseminated batch it dominates.

View change: follower timeout -> view++ (rotating leader); a new leader
runs phase-1 (modeled as one majority-RTT delay) before proposing. Requests
forwarded to a failed leader are lost to the count (client-retry is not
modeled; noted in DESIGN.md §8) — the crash-dip in fig7 is the phenomenon
under study.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.smr import SMRConfig
from repro.core import channel as ch
from repro.core import netsim, workload
from repro.obs import monitor as hmon
from repro.obs import trace as obs

def _phase1_ticks(cfg: SMRConfig) -> jnp.ndarray:
    """Majority RTT per prospective leader (modeled phase-1 cost)."""
    d = cfg.delays_ms() / cfg.tick_ms
    n = cfg.n_replicas
    maj = n // 2 + 1
    rtts = np.sort(2 * d, axis=1)[:, maj - 1]
    return jnp.asarray(rtts, jnp.float32)


def ring_spec(n: int, mandator_mode: bool) -> ch.RingSpec:
    """Packed delivery ring. The additive request-forward channel only
    exists in plain mode (mandator mode orders vector clocks, clients
    never forward), so its fields drop out of the ring entirely there."""
    channels = () if mandator_mode else (
        ch.ChannelSpec("fw", 2, additive=True),)      # (count, tsum)
    return ch.RingSpec(
        *channels,
        ch.ChannelSpec("acc", 3 + n),                 # (view, slot, ., vc)
        ch.ChannelSpec("ack", 1),
    )


def init_state(cfg: SMRConfig, n_ticks: int, mandator_mode: bool,
               closed: bool = False) -> Dict:
    n = cfg.n_replicas
    dmax = cfg.delay_horizon_ticks
    # flight recorder: absent at trace_level="off" (see mandator.init_state)
    tr = obs.init_trace(obs.DEFAULT_SPEC, cfg.trace_level, n,
                        cfg.trace_events)
    extra = {"tr": tr} if tr is not None else {}
    # health monitor per-tick IO gauges: absent at monitor_level="off"
    if hmon.on(cfg.monitor_level):
        extra["mon_io"] = {"dropped": jnp.zeros((n,), jnp.int32)}
    return {
        **extra,
        "wl": workload.init_workload(cfg, n_ticks,
                                     closed=closed and not mandator_mode),
        "view": jnp.zeros((n,), jnp.int32),
        "last_heard": jnp.zeros((n,), jnp.float32),
        "ready_at": jnp.zeros((n,), jnp.float32),
        "slot": jnp.zeros((n,), jnp.int32),           # leader's last started
        "outstanding": jnp.zeros((n,), jnp.bool_),
        "acks": jnp.zeros((n, n), jnp.int32),         # max slot acked by j
        "committed_slot": jnp.zeros((n,), jnp.int32),
        "cvc": jnp.zeros((n, n), jnp.int32),          # mandator mode commit VC
        "slot_vc": jnp.zeros((n, 1 + n), jnp.float32),  # outstanding slot payload
        "ring": ch.make_ring(ring_spec(n, mandator_mode), dmax, n),
        "egress_busy": jnp.zeros((n,), jnp.float32),
        "phase1": _phase1_ticks(cfg),
    }


def tick(st: Dict, t: jax.Array, key: jax.Array, env: Dict, cfg: SMRConfig,
         rate_per_tick: jax.Array, mandator_mode: bool,
         lcr: jax.Array | None = None, wlt: Dict | None = None,
         mode: workload.WorkloadMode = workload.TRIVIAL_MODE) -> Dict:
    n = cfg.n_replicas
    maj = n // 2 + 1
    alive = netsim.alive(env, t)
    delays = netsim.link_delay(env, t).astype(jnp.int32)
    drop = netsim.link_drop(env, t)
    to_ticks = jnp.float32(cfg.view_timeout_ms / cfg.tick_ms)
    tf = t.astype(jnp.float32)
    st = dict(st)
    rows = jnp.arange(n)

    view = st["view"]
    leader = view % n
    i_am_leader = (leader == rows) & alive
    # one fused pop of slot t for every channel; sends buffer up and commit
    # as one fused scatter at the end of the tick (same-tick sends always
    # land at t+1 or later, so the reorder is exact — channel.py)
    spec = ring_spec(n, mandator_mode)
    msgs = ch.ring_deliver(spec, st["ring"], t)
    sends = []

    wl = workload.refill_cpu(st["wl"], env["cpu_req_per_tick"])

    # ---- request forwarding (plain mode) ----------------------------------
    if not mandator_mode:
        wl = workload.arrive(wl, key, t, rate_per_tick, alive, wlt, mode)
        # forward whole local buffer to my current leader
        cnt = wl["buffer"]
        tsum = wl["buffer_tsum"]
        fw_pay = jnp.stack([cnt, tsum], axis=-1)[:, None, :] * jnp.ones((n, n, 1))
        # the leader keeps local arrivals in its own pool (no self-forward)
        fw_mask = (jnp.arange(n)[None, :] == leader[:, None]) & alive[:, None] \
            & (cnt > 0)[:, None] & (rows != leader)[:, None]
        sends.append(ch.Send("fw", fw_pay, delays, fw_mask))
        wl = dict(wl)
        # the forward channel is additive (counters), so a scenario-dropped
        # link is NOT a tolerable omission: keep the batch buffered and
        # retry next tick instead of destroying the requests
        sent = (fw_mask & ~drop).any(axis=1)
        wl["buffer"] = jnp.where(sent, 0.0, wl["buffer"])
        wl["buffer_tsum"] = jnp.where(sent, 0.0, wl["buffer_tsum"])
        # leader pools forwarded requests
        ffl, fpay = msgs["fw"]
        pool_cnt = jnp.sum(jnp.where(ffl[..., None], fpay, 0.0), axis=0)  # [rcv,2]
        wl["buffer"] = wl["buffer"] + pool_cnt[:, 0]
        wl["buffer_tsum"] = wl["buffer_tsum"] + pool_cnt[:, 1]

    # ---- deliver acks; leader commit ---------------------------------------
    afl, apay = msgs["ack"]
    acks = ch.fold_state(st["acks"].astype(jnp.float32)[..., None], afl, apay
                         )[..., 0].astype(jnp.int32)
    ack_cnt = jnp.sum(acks >= st["slot"][:, None], axis=1)
    commit = i_am_leader & st["outstanding"] & (ack_cnt >= maj)
    committed_slot = jnp.where(commit, st["slot"], st["committed_slot"])
    outstanding = st["outstanding"] & ~commit
    # record commit time of the slot batch (plain) / advance VC (mandator)
    if mandator_mode:
        cvc = jnp.where(commit[:, None],
                        jnp.maximum(st["cvc"], st["slot_vc"][:, 1:].astype(jnp.int32)),
                        st["cvc"])
    else:
        cvc = st["cvc"]
        # commit times are recorded post-hoc from the committed_slot trace
    # ---- leader proposes next slot -----------------------------------------
    can_prop = i_am_leader & ~outstanding & (tf >= st["ready_at"])
    if mandator_mode:
        have = (lcr[rows] > cvc).any(axis=1) if lcr is not None else False
        have = have & can_prop
        slot = jnp.where(have, st["slot"] + 1, st["slot"])
        pay_vc = jnp.where(have[:, None], lcr[rows].astype(jnp.float32),
                           st["slot_vc"][:, 1:])
        slot_vc = jnp.concatenate(
            [slot[:, None].astype(jnp.float32), pay_vc], axis=1)
        size_bytes = jnp.where(have, jnp.float32(cfg.meta_bytes), 0.0)
        formed = have
        count = jnp.zeros((n,))
    else:
        wl, formed, count = workload.form_batches(
            wl, t, can_prop, st["slot"] + 1, cfg.batch_paxos,
            cfg.max_batch_ms / cfg.tick_ms)
        slot = jnp.where(formed, st["slot"] + 1, st["slot"])
        slot_vc = st["slot_vc"]
        size_bytes = jnp.where(formed, count * cfg.request_bytes + 100.0, 0.0)
    outstanding = outstanding | formed
    # egress serialization (monolithic payload cost)
    bytes_out = jnp.broadcast_to(size_bytes[:, None], (n, n)) \
        / netsim.nic_rate(env, t)[:, None]
    busy, ser = netsim.egress_delay(st["egress_busy"], t, bytes_out)
    busy = jnp.where(formed, busy, st["egress_busy"])
    total_delay = (delays + jnp.where(formed[:, None], ser, 0.0)).astype(jnp.int32)
    acc_pay = jnp.concatenate([
        view[:, None].astype(jnp.float32), slot[:, None].astype(jnp.float32),
        jnp.zeros((n, 1)),
        slot_vc[:, 1:] if mandator_mode else jnp.zeros((n, n))], axis=1
        )[:, None, :] * jnp.ones((n, n, 1))
    sends.append(ch.Send("acc", acc_pay, total_delay,
                         formed[:, None] & jnp.ones((n, n), jnp.bool_)))

    # ---- follower: deliver accepts, ack, heartbeat --------------------------
    cfl, cpay = msgs["acc"]
    arr = jnp.swapaxes(cpay, 0, 1)
    afl2 = jnp.swapaxes(cfl, 0, 1)
    got = afl2.any(axis=1)
    mx = jnp.max(jnp.where(afl2[..., None], arr, -1.0), axis=1)
    acc_view = mx[:, 0].astype(jnp.int32)
    acc_slot = mx[:, 1].astype(jnp.int32)
    fresh = got & (acc_view >= view) & alive
    view = jnp.where(fresh, acc_view, view)
    last_heard = jnp.where(fresh, tf, st["last_heard"])
    # ack to the slot's leader
    ack_mask = fresh[:, None] & (jnp.arange(n)[None, :] == (view % n)[:, None])
    ack_pay = acc_slot.astype(jnp.float32)[:, None, None] * jnp.ones((n, n, 1))
    sends.append(ch.Send("ack", ack_pay, delays, ack_mask))

    # ---- view change ---------------------------------------------------------
    expired = alive & (tf - last_heard > to_ticks)
    view = jnp.where(expired, view + 1, view)
    last_heard = jnp.where(expired, tf, last_heard)
    became_leader = expired & ((view % n) == rows)
    ready_at = jnp.where(became_leader, tf + st["phase1"], st["ready_at"])

    ring = ch.ring_commit(spec, st["ring"], t, sends, drop=drop,
                          backend=cfg.channel_backend)

    # ---- flight recorder + monitor IO (absent => compiled out) ------------
    tr = st.get("tr")
    if tr is not None or "mon_io" in st:
        sent_any = sends[0].mask
        for s in sends[1:]:
            sent_any = sent_any | s.mask
        cut = jnp.sum(sent_any & drop, axis=1)
    if tr is not None:
        es = obs.DEFAULT_SPEC
        tr = obs.record(es, tr, "view_change", view != st["view"], t,
                        a=view, b=slot)
        tr = obs.record(es, tr, "leader_change", became_leader, t,
                        a=view % n, b=view)
        tr = obs.record(es, tr, "commit", commit, t, a=committed_slot,
                        b=ack_cnt)
        tr = obs.record(es, tr, "batch_create", formed, t, a=slot, b=count)
        tr = obs.record(es, tr, "batch_disseminate", formed, t, a=slot,
                        b=jnp.max(ser, axis=1))
        tr = obs.record_env(es, tr, alive, t, a=view, b=slot,
                            dropped_links=cut)
        st["tr"] = tr
    if "mon_io" in st:
        st["mon_io"] = {"dropped": cut.astype(jnp.int32)}

    st.update(wl=wl, view=view, last_heard=last_heard, ready_at=ready_at,
              slot=slot, outstanding=outstanding, acks=acks,
              committed_slot=committed_slot, cvc=cvc, slot_vc=slot_vc,
              ring=ring, egress_busy=busy)
    return st
