"""Composable traffic-shape primitives — the client side of the paper's
§5.2–§5.5 experiments, made declarative the same way scenarios made the
network adversary declarative.

Each primitive is a frozen dataclass with a time window (seconds) and knows
how to *paint* itself onto the windowed rate table the compiler builds
(see compile.py):

  rate_of[w, n]   per-origin rate multiplier, 1.0 = the origin's uniform
                  share of the sweep's offered rate (so an all-ones table
                  is exactly the seed-era colocated open-loop Poisson load)

Composition rules (primitives are applied in Workload order):
  scalers        (PoissonOpen, OnOffBurst, DiurnalRamp, FlashCrowd)
                 — multiplicative on the rows/origins they cover,
  redistributors (RegionSkew, ClosedLoop placement)
                 — replace the per-origin split of a window while
                 conserving that window's total offered load.

Windows are maximal intervals between the union of all primitives' tick
edges, so every table row is constant over its window by construction;
time-varying shapes (ramps, decays) are evaluated at the window midpoint.

``ClosedLoop`` switches the workload from open-loop (rate is offered
regardless of progress) to closed-loop (Atlas-style geo-placed client
pools): the sweep rate sets the client population via Little's law
(clients = rate x think time), each pool submits at
(clients - in_flight) / think_ticks, and arrivals are additionally capped
so per-origin in-flight never exceeds ``cap``. The in-flight decrement at
commit lives inside the simulator's scan carry (core/harness.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.configs.smr import SMRConfig
from repro.scenarios.primitives import Targets, _covered, _tick, resolve_targets

Tables = dict


@dataclass(frozen=True)
class Workload:
    """A named, ordered composition of traffic-shape primitives."""
    name: str = "poisson-open"
    shapes: Tuple = ()


def _redistribute(tab: Tables, rows: np.ndarray, weights: np.ndarray) -> None:
    """Replace covered rows' per-origin split with ``weights`` (sum 1),
    conserving each row's total offered load."""
    totals = tab["rate_of"][rows].sum(axis=1, keepdims=True)
    tab["rate_of"][rows] = totals * weights[None, :]


@dataclass(frozen=True)
class PoissonOpen:
    """The seed-era baseline: open-loop Poisson arrivals, colocated with
    every replica, at ``scale`` x the uniform share. scale=1.0 compiles to
    the all-ones table (the provably-identical fast path)."""
    scale: float = 1.0

    def edges(self, cfg: SMRConfig, n_ticks: int):
        return ()

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        # lint: allow(dtype-hygiene): host-side f64 rate painting; one f32 cast at compile.lower()
        tab["rate_of"] *= np.float64(self.scale)


@dataclass(frozen=True)
class OnOffBurst:
    """Square-wave traffic: each ``period_s`` the targets send at
    ``on_scale`` for ``duty`` of the period, then ``off_scale`` for the
    rest, over [start_s, end_s)."""
    period_s: float
    duty: float = 0.5
    on_scale: float = 2.0
    off_scale: float = 0.0
    targets: Targets = "all"
    start_s: float = 0.0
    end_s: float = math.inf

    def edges(self, cfg: SMRConfig, n_ticks: int):
        if self.period_s <= 0 or not 0 < self.duty <= 1:
            raise ValueError("OnOffBurst needs period_s > 0, 0 < duty <= 1")
        t0 = _tick(cfg, self.start_s, n_ticks)
        t1 = _tick(cfg, self.end_s, n_ticks)
        out = [t0, t1]
        k = 0
        while True:
            on = _tick(cfg, self.start_s + k * self.period_s, n_ticks)
            off = _tick(cfg, self.start_s + (k + self.duty) * self.period_s,
                        n_ticks)
            if on >= t1 and off >= t1:
                break
            out += [on, off]
            k += 1
        return tuple(e for e in out if t0 <= e <= t1)

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        t0 = _tick(cfg, self.start_s, n_ticks)
        t1 = _tick(cfg, self.end_s, n_ticks)
        mask = resolve_targets(self.targets, tab["rate_of"].shape[1])
        period = max(self.period_s * 1000.0 / cfg.tick_ms, 1.0)
        for w in np.flatnonzero(_covered(win_start, t0, t1)):
            nxt = win_start[w + 1] if w + 1 < len(win_start) else n_ticks
            mid = (win_start[w] + nxt) / 2.0
            phase = ((mid - t0) % period) / period
            s = self.on_scale if phase < self.duty else self.off_scale
            # lint: allow(dtype-hygiene): host-side f64 rate painting; one f32 cast at compile.lower()
            tab["rate_of"][w, mask] *= np.float64(s)


@dataclass(frozen=True)
class DiurnalRamp:
    """Smooth day/night load cycle discretized to a staircase: total load
    ramps between ``low`` and ``high`` x baseline along a cosine of period
    ``period_s``, re-evaluated every ``step_s`` (at the step midpoint, so a
    whole period averages exactly (low+high)/2)."""
    period_s: float
    low: float = 0.25
    high: float = 1.75
    step_s: float = 0.25
    targets: Targets = "all"

    def edges(self, cfg: SMRConfig, n_ticks: int):
        step = max(1, _tick(cfg, self.step_s, n_ticks))
        return tuple(range(0, n_ticks, step)) + (n_ticks,)

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        mask = resolve_targets(self.targets, tab["rate_of"].shape[1])
        period = self.period_s * 1000.0 / cfg.tick_ms
        for w in range(len(win_start)):
            nxt = win_start[w + 1] if w + 1 < len(win_start) else n_ticks
            mid = (win_start[w] + nxt) / 2.0
            s = self.low + (self.high - self.low) * 0.5 * (
                1.0 - math.cos(2.0 * math.pi * mid / period))
            # lint: allow(dtype-hygiene): host-side f64 rate painting; one f32 cast at compile.lower()
            tab["rate_of"][w, mask] *= np.float64(s)


@dataclass(frozen=True)
class FlashCrowd:
    """A sudden crowd at the target regions: load jumps to ``magnitude`` x
    over [at_s, at_s + duration_s), then decays back exponentially over
    ``decay_s`` (staircase, ``decay_steps`` windows; decay_s=0 is a clean
    rectangle — the analytically-exact form the conservation tests pin)."""
    at_s: float
    duration_s: float = 0.5
    magnitude: float = 8.0
    targets: Targets = "all"
    decay_s: float = 0.0
    decay_steps: int = 6

    def edges(self, cfg: SMRConfig, n_ticks: int):
        t0 = _tick(cfg, self.at_s, n_ticks)
        t1 = _tick(cfg, self.at_s + self.duration_s, n_ticks)
        out = [t0, t1]
        if self.decay_s > 0:
            step = self.decay_s / self.decay_steps
            out += [_tick(cfg, self.at_s + self.duration_s + k * step,
                          n_ticks) for k in range(1, self.decay_steps + 1)]
        return tuple(out)

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        mask = resolve_targets(self.targets, tab["rate_of"].shape[1])
        t0 = _tick(cfg, self.at_s, n_ticks)
        t1 = _tick(cfg, self.at_s + self.duration_s, n_ticks)
        w = _covered(win_start, t0, t1)
        # lint: allow(dtype-hygiene): host-side f64 rate painting; one f32 cast at compile.lower()
        tab["rate_of"][np.ix_(w, mask)] *= np.float64(self.magnitude)
        if self.decay_s > 0:
            t2 = _tick(cfg, self.at_s + self.duration_s + self.decay_s,
                       n_ticks)
            tau = self.decay_s * 1000.0 / cfg.tick_ms / 3.0
            for wi in np.flatnonzero(_covered(win_start, t1, t2)):
                nxt = win_start[wi + 1] if wi + 1 < len(win_start) else n_ticks
                mid = (win_start[wi] + nxt) / 2.0
                s = 1.0 + (self.magnitude - 1.0) * math.exp(-(mid - t1) / tau)
                # lint: allow(dtype-hygiene): host-side f64 rate painting; one f32 cast at compile.lower()
                tab["rate_of"][wi, mask] *= np.float64(s)


@dataclass(frozen=True)
class RegionSkew:
    """WPaxos-style locality: ``hot_frac`` of the total offered load comes
    from the ``hot`` regions, the rest is shared evenly by the others —
    and, with ``migrate_s``, the hotspot *moves* to the next region (mod n)
    every ``migrate_s`` seconds (the locality-shifting access pattern
    WPaxos is built around). Conserves each window's total load."""
    hot_frac: float = 0.8
    hot: Tuple[int, ...] = (0,)
    migrate_s: Optional[float] = None
    start_s: float = 0.0
    end_s: float = math.inf

    def _migrate_ticks(self, cfg: SMRConfig) -> int:
        assert self.migrate_s is not None
        return max(1, int(self.migrate_s * 1000.0 / cfg.tick_ms))

    def edges(self, cfg: SMRConfig, n_ticks: int):
        t0 = _tick(cfg, self.start_s, n_ticks)
        t1 = _tick(cfg, self.end_s, n_ticks)
        if self.migrate_s is None:
            return (t0, t1)
        return tuple(range(t0, t1 if math.isfinite(self.end_s) else n_ticks,
                           self._migrate_ticks(cfg))) + (t1,)

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        n = tab["rate_of"].shape[1]
        t0 = _tick(cfg, self.start_s, n_ticks)
        t1 = _tick(cfg, self.end_s, n_ticks)
        n_hot = len(self.hot)
        if not 0 < n_hot < n:
            raise ValueError("RegionSkew.hot must be a proper subset")
        for w in np.flatnonzero(_covered(win_start, t0, t1)):
            shift = 0 if self.migrate_s is None else \
                (int(win_start[w]) - t0) // self._migrate_ticks(cfg)
            weights = np.full((n,), (1.0 - self.hot_frac) / (n - n_hot))
            for h in self.hot:
                weights[(h + shift) % n] = self.hot_frac / n_hot
            _redistribute(tab, np.array([w]), weights)


@dataclass(frozen=True)
class ClosedLoop:
    """Geo-placed closed-loop client pools (Atlas-style): the sweep rate
    sets the total client population via Little's law
    (clients = rate_tx_s x think_ms), split across regions by
    ``placement`` (None = uniform; else per-region weights, normalized).
    Each pool submits at (clients - in_flight)/think ticks and never holds
    more than ``cap`` requests in flight per origin; the in-flight count is
    decremented when the batch carrying a request commits (the feedback
    lives in the scan carry, core/harness.py)."""
    think_ms: float = 50.0
    cap: float = 4000.0
    placement: Optional[Tuple[float, ...]] = None

    def edges(self, cfg: SMRConfig, n_ticks: int):
        return ()

    def paint(self, cfg: SMRConfig, n_ticks: int, win_start: np.ndarray,
              tab: Tables) -> None:
        n = tab["rate_of"].shape[1]
        if tab["closed"]:
            raise ValueError("a Workload may contain only one ClosedLoop")
        if self.placement is not None:
            # lint: allow(dtype-hygiene): host-side f64 rate painting; one f32 cast at compile.lower()
            w = np.asarray(self.placement, np.float64)
            if w.shape != (n,) or (w < 0).any() or w.sum() <= 0:
                raise ValueError(
                    f"placement must be {n} non-negative weights")
            _redistribute(tab, np.arange(tab["rate_of"].shape[0]),
                          w / w.sum())
        tab["closed"] = True
        tab["think_ticks"] = max(self.think_ms / cfg.tick_ms, 1.0)
        tab["cap"] = float(self.cap)
