"""WAN network environment: per-pair delays, NIC egress serialization, and
scenario-driven adversities (crash intervals, partitions, regional outages,
gray failures, the §5.5 targeted-minority DDoS, bandwidth throttles).

``build_env`` is fully array-native: every leaf of the returned dict is a
fixed-shape ``jnp`` array (no Python scalars), so environments built from
different scenarios can be stacked leaf-wise (``stack_envs``) and the whole
tick loop vmapped over the stacked axis by the batched experiment engine
(core/experiment.py).

Adverse conditions come in as *windowed tables* compiled from a declarative
``repro.scenarios.Scenario`` (see scenarios/compile.py): the run is cut
into W windows over which everything is constant, and the env carries
``win_of_tick [n_ticks]`` plus per-window ``alive_tab [W, n]``,
``drop_tab [W, n, n]``, ``delay_tab [W, n, n]`` (extra ticks), and
``nic_tab [W, n]`` (egress scale). Pass ``n_windows`` to pad the tables to
a common width before stacking; padding rows are never read because
``win_of_tick`` only indexes real windows.

The channel rings that carry the traffic are sized by the **delay
horizon**; ``resolve_horizon`` computes the exact per-sweep bound from the
compiled scenario tables when ``SMRConfig.delay_horizon_ticks="auto"``
(static link delay + max scenario extra delay + a NIC-backlog bound, next
power of two) — per-tick channel cost is linear in the ring size, so this
is what keeps the fig-suite rings at their true size instead of a fixed
worst-case 2048.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.smr import SMRConfig


def sim_ticks(cfg: SMRConfig) -> int:
    """Number of simulator ticks — static (known at trace time)."""
    return int(cfg.sim_seconds * 1000 / cfg.tick_ms)


def env_windows(cfg: SMRConfig, scenario) -> int:
    """Windowed-table rows this scenario lowers to — used to pick a common
    pad width before stacking envs."""
    from repro import scenarios
    return scenarios.compile.n_windows(cfg, scenarios.as_scenario(scenario))


# extra slots past the provable static bound: absorbs rounding and the
# sub-tick serialization remainders without changing the power-of-two size
# in practice
_HORIZON_MARGIN_TICKS = 16

# Canonical ring-size floor for ``resolve_horizon(..., canonical=True)``:
# the fig 6/7/9 suites (and everything at the paper's 5-replica WAN) all
# resolve to exactly 256, so rounding smaller sweeps up to it merges their
# otherwise-distinct 64/128-slot programs into the one canonical
# (n, K, W, Dmax) signature per protocol. A larger ring never changes
# results (it only adds slots past the sweep's true delay bound — pinned
# by tests/test_scenarios.py), it only costs per-tick work, which is ~free
# since the packed-ring substrate.
CANONICAL_HORIZON = 256


def _backlog_bound_ticks(cfg: SMRConfig, min_nic_scale: float) -> float:
    """Upper bound on NIC egress queueing delay (ticks). Batch formation is
    completion-gated in every protocol (one outstanding slot for paxos,
    ``mandator_lanes`` chained batches for mandator), so at most that many
    maximal batches can queue on one sender's NIC at once; each serializes
    to all n receivers at the (throttle-scaled) egress rate. A fully cut
    NIC (scale <= 0) has no finite bound — the caller caps the horizon at
    the sim length, past which delivery times are unobservable anyway."""
    if min_nic_scale <= 0.0:
        return np.inf
    bytes_per_tick = cfg.nic_gbps * 1e9 / 8.0 * cfg.tick_ms / 1000.0
    max_batch_bytes = (max(cfg.batch_paxos, cfg.batch_mandator,
                           cfg.batch_sporades) * cfg.request_bytes + 100.0)
    outstanding = max(1, cfg.mandator_lanes)
    return outstanding * cfg.n_replicas * max_batch_bytes / (
        bytes_per_tick * float(min_nic_scale))


def resolve_horizon(cfg: SMRConfig, scenarios_=(), tabs=None,
                    canonical: bool = False) -> SMRConfig:
    """Resolve ``delay_horizon_ticks="auto"`` to the exact bound for a
    sweep: max static link delay + the largest scenario ``extra_delay`` +
    the NIC-backlog bound under the worst scenario throttle, next power of
    two. The bound is capped at one sim length: a ring spanning the whole
    run clips only deliveries that would land after the sim ends — which
    no horizon could observe — so the cap keeps the sound-bound contract
    even when a harsh ``BandwidthThrottle`` makes the raw backlog bound
    huge. Must be called with EVERY scenario of a sweep so all grid points
    share one ring shape (one compiled program); pass ``tabs`` (their
    pre-lowered, unpadded tables) to avoid re-lowering. No-op on int
    horizons — a pinned ring is user intent, canonicalization only rounds
    "auto". With ``canonical=True`` the resolved size is additionally
    floored at ``CANONICAL_HORIZON`` so shape-compatible sweeps land on
    the one canonical program signature per protocol."""
    if isinstance(cfg.delay_horizon_ticks, int):
        return cfg
    if cfg.delay_horizon_ticks != "auto":
        raise ValueError(
            f"delay_horizon_ticks must be an int or 'auto', got "
            f"{cfg.delay_horizon_ticks!r}")
    if tabs is None:
        from repro import scenarios as sc
        tabs = [sc.lower(cfg, sc.as_scenario(s)) for s in scenarios_]
    extra = 0.0
    min_scale = 1.0
    for tab in tabs:
        extra = max(extra, float(np.max(tab["extra_delay"], initial=0.0)))
        min_scale = min(min_scale, float(np.min(tab["nic_scale"],
                                                initial=1.0)))
    bound = (np.max(cfg.delays_ms()) / cfg.tick_ms + extra
             + _backlog_bound_ticks(cfg, min_scale) + _HORIZON_MARGIN_TICKS)
    bound = min(float(bound), float(sim_ticks(cfg) + 1))
    horizon = max(64, 1 << max(0, int(np.ceil(bound)) - 1).bit_length())
    if canonical:
        horizon = max(horizon, CANONICAL_HORIZON)
    return dataclasses.replace(cfg, delay_horizon_ticks=int(horizon))


def build_env(cfg: SMRConfig, scenario=None,
              n_windows: Optional[int] = None,
              tab=None) -> Dict[str, jnp.ndarray]:
    """scenario: a repro.scenarios.Scenario or None (fault-free baseline).
    tab: its pre-lowered (unpadded) tables, if the caller already has them
    (experiment._lower computes them once per sweep for the horizon)."""
    from repro import scenarios
    n = cfg.n_replicas
    if tab is None:
        tab = scenarios.lower(cfg, scenarios.as_scenario(scenario))
    pinned = isinstance(cfg.delay_horizon_ticks, int)
    cfg = resolve_horizon(cfg, tabs=[tab])
    if n_windows is not None:
        tab = scenarios.compile.pad_tables(tab, n_windows)
    # Channels cap a message's total delay at delay_horizon_ticks - 1
    # (channel.send clips); NIC backlog beyond the horizon is delivered at
    # the horizon by design, but a *static* link + scenario delay exceeding
    # a PINNED horizon is a misconfiguration that would silently distort
    # every message. An "auto" horizon only ever falls short of the static
    # delay when capped at the sim length — and a ring spanning the run
    # clips deliveries to at/after the last tick, where nothing is
    # observable, so that case is sound and passes.
    static_delay = (np.max(cfg.delays_ms()) / cfg.tick_ms
                    + float(np.max(tab["extra_delay"], initial=0.0)))
    if static_delay >= cfg.delay_horizon_ticks and (
            pinned or cfg.delay_horizon_ticks - 1 < sim_ticks(cfg)):
        raise ValueError(
            f"link + scenario delay ({static_delay:.0f} ticks) exceeds "
            f"delay_horizon_ticks={cfg.delay_horizon_ticks}; raise the "
            "horizon in SMRConfig")
    return {
        "delays": jnp.asarray(cfg.delays_ms() / cfg.tick_ms),  # [n,n] ticks
        "win_of_tick": jnp.asarray(tab["win_of_tick"]),        # [n_ticks]
        "alive_tab": jnp.asarray(tab["alive"]),                # [W,n]
        "drop_tab": jnp.asarray(tab["drop"]),                  # [W,n,n]
        "delay_tab": jnp.asarray(tab["extra_delay"]),          # [W,n,n]
        "nic_tab": jnp.asarray(tab["nic_scale"]),              # [W,n]
        "bytes_per_tick": jnp.float32(
            cfg.nic_gbps * 1e9 / 8.0 * cfg.tick_ms / 1000.0),
        "cpu_req_per_tick": jnp.float32(
            cfg.tick_ms * 1000.0 / cfg.cpu_us_per_request),
    }


def stack_envs(envs: Sequence[Dict[str, jnp.ndarray]]) -> Dict[str, jnp.ndarray]:
    """Stack envs leaf-wise into a batched env (leading axis = variant).
    All envs must come from the same cfg and a common ``n_windows``."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *envs)


def _win(env, t) -> jax.Array:
    """Window row for tick t (scalar int32)."""
    return env["win_of_tick"][t]


def alive(env, t) -> jax.Array:
    """[n] bool — replica is up in tick t's window."""
    return env["alive_tab"][_win(env, t)]


def link_delay(env, t) -> jax.Array:
    """[n, n] delay in ticks including scenario extra delay (DDoS, outage
    turbulence, gray jitter)."""
    return env["delays"] + env["delay_tab"][_win(env, t)]


def link_drop(env, t) -> jax.Array:
    """[n, n] bool — links the scenario cuts this tick (partitions, gray
    loss). Feed to channel.send's drop mask."""
    return env["drop_tab"][_win(env, t)]


def nic_rate(env, t) -> jax.Array:
    """[n] effective egress bytes/tick per sender (throttle-scaled)."""
    return env["bytes_per_tick"] * env["nic_tab"][_win(env, t)]


def egress_delay(busy: jax.Array, t: jax.Array, bytes_out: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """NIC serialization. busy: [n] abs tick when NIC frees; bytes_out: [n,n]
    bytes sent this tick (serialized in receiver order). Returns
    (new_busy [n], extra_delay_ticks [n,n])."""
    # cumulative serialization time per receiver j (order: j ascending)
    # NOTE: the effective nic_rate is folded in by the caller.
    cum = jnp.cumsum(bytes_out, axis=1)
    start = jnp.maximum(busy, t.astype(jnp.float32))[:, None]
    finish = start + cum
    new_busy = start[:, 0] + cum[:, -1]
    return new_busy, finish - t.astype(jnp.float32)
