"""R1 traced-purity: host numpy reachable from a traced root."""
import numpy as np


# lint: traced-root
def body(state, msg):
    acc = np.sum(state)  # expect: R1
    return acc, msg
