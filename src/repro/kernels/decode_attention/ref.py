"""Pure-jnp oracle for the flash-decoding kernel: one query token against a
(possibly partially filled) KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array) -> jax.Array:
    """q: [B, H, D]; k, v: [B, Kh, S, D]; kv_len: [B] — positions >= kv_len
    are masked. Returns [B, H, D] (fp32 softmax)."""
    b, h, d = q.shape
    kh, s = k.shape[1], k.shape[2]
    qg = q.reshape(b, kh, h // kh, d)
    logits = jnp.einsum("bkgd,bktd->bkgt", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(d))
    valid = jnp.arange(s)[None, :] < kv_len[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", p.astype(v.dtype), v)
    return out.reshape(b, h, d)
