"""Protocol flight recorder (observability substrate).

Three layers, consensus-agnostic:

  - ``obs.trace``  — on-device event rings + counters, vmap-safe, carried
    inside the protocol scan; statically gated by ``SMRConfig.trace_level``
    so ``off`` (the default) compiles to the identical program;
  - ``obs.decode`` — host-side ring -> per-replica event timelines;
  - ``obs.export`` — Chrome/Perfetto ``trace_event`` JSON + the per-phase
    latency table (``benchmarks/inspect.py`` and the demo's ``--trace``
    drive both).

See docs/ARCHITECTURE.md "Observability".
"""
from repro.obs import decode, export  # noqa: F401
from repro.obs.trace import (  # noqa: F401
    DEFAULT_SPEC, FIELDS, PHASES, TRACE_ENV, HostTrace, TraceLevel,
    TraceSpec, init_trace, level_from_env, public_view, record, record_env,
)
