"""Pure-jnp oracle for the fused (residual-add +) RMSNorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
                residual: jax.Array | None = None) -> jax.Array:
    """x: [..., D], w: [D]. Residual-add and statistics in fp32 (the fused
    kernel's semantics), output in x.dtype."""
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w).astype(x.dtype)
