"""Jit'd wrapper: arbitrary leading dims, CPU-interpret fallback."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_2d


@partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
            residual: jax.Array | None = None,
            interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    n = 1
    for s_ in shape[:-1]:
        n *= s_
    x2 = x.reshape(n, shape[-1])
    r2 = None if residual is None else residual.reshape(n, shape[-1])
    bn = 256
    while n % bn and bn > 1:
        bn //= 2
    out = rmsnorm_2d(x2, w, eps=eps, residual=r2, bn=bn, interpret=interpret)
    return out.reshape(shape)
