"""Pure-jnp oracle for the selective-scan kernel: sequential recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
                 A: jax.Array, D: jax.Array) -> jax.Array:
    """x, dt: [Bt, S, Di]; B, C: [Bt, S, N]; A: [Di, N]; D: [Di].

    h_t = exp(dt_t * A) h_{t-1} + (dt_t * x_t) B_t ;  y_t = h_t . C_t + D x_t
    """
    bsz, s, di = x.shape
    n = A.shape[1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        dtf = dtt.astype(jnp.float32)
        a = jnp.exp(dtf[:, :, None] * A)                        # [Bt,Di,N]
        h = a * h + (dtf * xt.astype(jnp.float32))[:, :, None] * bt[:, None, :].astype(jnp.float32)
        y = jnp.einsum("bin,bn->bi", h, ct.astype(jnp.float32))
        return h, y

    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (x.swapaxes(0, 1), dt.swapaxes(0, 1),
                          B.swapaxes(0, 1), C.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).astype(x.dtype)
    return y + x * D
