"""Batched serving driver: prefill + decode with the KV/recurrent cache.

CPU-runnable with reduced configs (quickstart/examples); the decode step is
the same function the dry-run lowers against the production mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import (CallConfig, forward_train, forward_decode,
                          init_cache, init_params)


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 16, gen: int = 32, seed: int = 0,
          greedy: bool = True, verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    call = CallConfig(compute_dtype=jnp.float32, attention_impl="dense",
                      remat=False)
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    max_seq = prompt_len + gen
    cache = init_cache(cfg, batch, max_seq, jnp.float32)

    pbatch: Dict = {}
    if cfg.embed_inputs:
        pbatch["tokens"] = jax.random.randint(key, (batch, prompt_len), 0,
                                              cfg.vocab)
    else:
        pbatch["frame_emb"] = 0.02 * jax.random.normal(
            key, (batch, prompt_len, cfg.d_model))
    if cfg.cross_attn is not None:
        pbatch["vision_mem"] = 0.02 * jax.random.normal(
            key, (batch, cfg.cross_attn.n_mem_tokens, cfg.d_model))

    decode = jax.jit(lambda p, c, b, pos: forward_decode(p, cfg, call, b, c,
                                                         pos))
    # prefill token-by-token (cache-exact; a fused prefill kernel is the
    # attention_impl="pallas" path on TPU)
    t0 = time.time()
    tok = None
    for t in range(prompt_len):
        db = dict(pbatch)
        if cfg.embed_inputs:
            db["tokens"] = pbatch["tokens"][:, t]
        else:
            db["frame_emb"] = pbatch["frame_emb"][:, t:t + 1]
        logits, cache = decode(params, cache, db, jnp.int32(t))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    for t in range(prompt_len, max_seq - 1):
        db = dict(pbatch)
        if cfg.embed_inputs:
            db["tokens"] = tok
        else:
            db["frame_emb"] = 0.0 * pbatch["frame_emb"][:, :1]
        logits, cache = decode(params, cache, db, jnp.int32(t))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    toks = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    if verbose:
        print(f"[serve] {arch}: batch={batch} prompt={prompt_len} "
              f"gen={len(out_tokens)} in {dt:.1f}s "
              f"({batch * len(out_tokens) / dt:.1f} tok/s)")
        print("first sequence:", toks[0, :16])
    return {"tokens": toks, "seconds": dt}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    serve(args.arch, reduced=args.reduced, batch=args.batch,
          prompt_len=args.prompt_len, gen=args.gen)


if __name__ == "__main__":
    main()
