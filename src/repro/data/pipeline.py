"""Deterministic synthetic token pipeline: seeded, shardable, restartable.

Every (step, host) pair derives its shard of the global batch purely from
(seed, step) — restart/elastic-rescale replay exact batches (the data-plane
analogue of Mandator's "replicas repeatedly propose until committed"). A
Zipfian unigram over the vocab + Markov low-order structure gives a
learnable distribution (loss decreases measurably within a few hundred
steps on the quickstart).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_exponent: float = 1.1
    markov_shift: int = 7     # next-token bias: x_{t+1} ~ (x_t * a + c) pattern


def _zipf_logits(vocab: int, exponent: float) -> jax.Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -exponent * jnp.log(ranks)


def global_batch(cfg: ModelConfig, shape: ShapeConfig, dcfg: DataConfig,
                 step: int | jax.Array) -> Dict[str, jax.Array]:
    """Materialize the full global batch for `step` (test/CPU scale)."""
    return batch_shard(cfg, shape, dcfg, step, shard=0, n_shards=1)


def batch_shard(cfg: ModelConfig, shape: ShapeConfig, dcfg: DataConfig,
                step: int | jax.Array, shard: int, n_shards: int
                ) -> Dict[str, jax.Array]:
    """The per-host shard of the global batch — pure function of
    (seed, step, shard)."""
    assert shape.global_batch % n_shards == 0
    b = shape.global_batch // n_shards
    s = shape.seq_len
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(dcfg.seed),
                           jnp.asarray(step, jnp.uint32)),
        jnp.asarray(shard, jnp.uint32))
    logits = _zipf_logits(cfg.vocab, dcfg.zipf_exponent)
    base = jax.random.categorical(key, logits, shape=(b, s + 1))
    # inject learnable sequential structure
    t = jnp.arange(s + 1)
    drift = (t * dcfg.markov_shift) % max(cfg.vocab // 7, 1)
    tokens = (base + drift[None, :]) % cfg.vocab
    out: Dict[str, jax.Array] = {}
    if cfg.embed_inputs:
        out["tokens"] = tokens[:, :s].astype(jnp.int32)
    else:
        emb_key = jax.random.fold_in(key, 1)
        out["frame_emb"] = 0.02 * jax.random.normal(
            emb_key, (b, s, cfg.d_model), jnp.float32)
    out["labels"] = tokens[:, 1:s + 1].astype(jnp.int32)
    if cfg.cross_attn is not None:
        mem_key = jax.random.fold_in(key, 2)
        out["vision_mem"] = 0.02 * jax.random.normal(
            mem_key, (b, cfg.cross_attn.n_mem_tokens, cfg.d_model),
            jnp.float32)
    return out
