"""llama-3.2-vision-11b — cross-attn image layers every 5th.  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Modality frontend is a STUB: input_specs() supplies precomputed ViT patch
embeddings (1601 tokens x d_model) as the cross-attention memory.
"""
from repro.configs.base import CrossAttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256,
    cross_attn=CrossAttnConfig(every=5, n_mem_tokens=1601),
    notes="text backbone + cross-attn to stubbed vision memory",
)
