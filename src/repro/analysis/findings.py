"""Findings, pragmas, and baselines shared by both tracelint layers.

A finding is one rule violation at one source span. Suppression is
per-line and must be justified:

    # lint: allow(<rule-key>): <why this host-side code is intentional>

on the flagged line itself or on a comment line directly above it. A
pragma without a justification is itself a finding (rule ``pragma``) —
the suppression mechanism cannot silently grow blanket excludes.

Baselines (``--baseline``) are JSON lists of ``{rule, file, message}``
triples: findings already present in the baseline are reported as
``baselined`` and do not fail the run, so the pass can be introduced
against a repo with known debt and still gate *new* violations. Line
numbers are deliberately not part of the baseline key (edits above a
finding must not un-baseline it).

Stdlib-only on purpose: the AST layer (and this module) must run in any
Python without jax installed — CI lints every push before it ever
builds a jax environment.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

# rule keys (the pragma vocabulary); R* = AST layer, H* = HLO layer
RULE_KEYS = {
    "R1": "traced-purity",
    "R2": "dtype-hygiene",
    "R3": "static-args",
    "R4": "drop-mask",
    "R5": "carry-hygiene",
    "H1": "hlo-f64",
    "H2": "hlo-host-transfer",
    "H3": "hlo-while",
    "H4": "hlo-signature",
    "P0": "pragma",
}
KEY_RULES = {v: k for k, v in RULE_KEYS.items()}

_PRAGMA_RE = re.compile(
    r"#\s*lint:\s*allow\(\s*([\w-]+)\s*\)\s*(?::\s*(\S.*))?")
_ROOT_RE = re.compile(r"#\s*lint:\s*traced-root\b")


@dataclass
class Finding:
    """One rule violation at one span. ``pragma`` records how suppression
    resolved: ``none`` (active — fails the run), ``allowed`` (justified
    pragma on the span), ``baselined`` (known debt from --baseline)."""
    rule: str        # "R1".."R5" / "H1".."H4" / "P0"
    key: str         # kebab rule key, the pragma vocabulary
    file: str        # repo-relative path ("<hlo>" for program findings)
    line: int
    col: int
    severity: str    # "error" | "warn"
    message: str
    pragma: str = "none"

    @property
    def active(self) -> bool:
        return self.pragma == "none" and self.severity == "error"

    def span(self) -> str:
        return f"{self.file}:{self.line}:{self.col}"


@dataclass
class Pragma:
    key: str
    line: int                  # line the pragma suppresses
    justification: str = ""
    used: bool = False


class PragmaTable:
    """Per-file suppression table. A pragma on a *comment-only* line
    covers the next code line; an end-of-line pragma covers its own."""

    def __init__(self, source: str, path: str):
        self.path = path
        self.by_line: Dict[Tuple[int, str], Pragma] = {}
        self.roots: List[int] = []     # `# lint: traced-root` marker lines
        self.unjustified: List[Pragma] = []
        lines = source.splitlines()
        for i, text in enumerate(lines, start=1):
            if _ROOT_RE.search(text):
                self.roots.append(i)
            m = _PRAGMA_RE.search(text)
            if not m:
                continue
            key, why = m.group(1), (m.group(2) or "").strip()
            target = i
            if text.lstrip().startswith("#"):
                # comment-only line: suppress the next non-comment line
                j = i
                while j < len(lines) and (not lines[j].strip()
                                          or lines[j].lstrip()
                                          .startswith("#")):
                    j += 1
                target = j + 1
            p = Pragma(key=key, line=target, justification=why)
            self.by_line[(target, key)] = p
            if not why:
                self.unjustified.append(p)

    def lookup(self, line: int, key: str) -> Optional[Pragma]:
        p = self.by_line.get((line, key))
        if p is not None:
            p.used = True
        return p

    def pragma_findings(self) -> List[Finding]:
        return [Finding(rule="P0", key="pragma", file=self.path,
                        line=p.line, col=0, severity="error",
                        message=f"pragma allow({p.key}) has no "
                                "justification — add `: <why>`")
                for p in self.unjustified]


@dataclass
class Report:
    findings: List[Finding] = field(default_factory=list)

    def extend(self, fs: Iterable[Finding]) -> None:
        self.findings.extend(fs)

    @property
    def active(self) -> List[Finding]:
        return [f for f in self.findings if f.active]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.active:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def apply_baseline(self, baseline: List[dict]) -> None:
        known = {(b.get("rule"), b.get("file"), b.get("message"))
                 for b in baseline}
        for f in self.findings:
            if f.pragma == "none" and (f.rule, f.file, f.message) in known:
                f.pragma = "baselined"

    def to_json(self) -> List[dict]:
        return [asdict(f) for f in sorted(
            self.findings, key=lambda f: (f.file, f.line, f.rule))]

    def baseline_json(self) -> List[dict]:
        return [{"rule": f.rule, "file": f.file, "message": f.message}
                for f in sorted(self.active,
                                key=lambda f: (f.file, f.line, f.rule))]


def findings_from_json(data: List[dict]) -> List[Finding]:
    """Rehydrate a findings list written by ``Report.to_json`` (the
    ``--json`` artifact consumed by ``benchmarks/inspect.py``)."""
    fields = {"rule", "key", "file", "line", "col", "severity",
              "message", "pragma"}
    return [Finding(**{k: v for k, v in d.items() if k in fields})
            for d in data]


def load_baseline(path) -> List[dict]:
    data = json.loads(Path(path).read_text())
    if not isinstance(data, list):
        raise ValueError(f"baseline {path} is not a JSON list")
    return data


def format_table(findings: List[Finding]) -> List[str]:
    """The findings table (rule, span, severity, pragma status, message)
    shared by the CLI and ``benchmarks/inspect.py --analysis``."""
    if not findings:
        return ["no findings"]
    rows = [("RULE", "WHERE", "SEV", "PRAGMA", "MESSAGE")]
    for f in sorted(findings, key=lambda f: (f.pragma != "none",
                                             f.file, f.line)):
        rows.append((f"{f.rule}/{f.key}", f.span(), f.severity,
                     f.pragma, f.message))
    widths = [max(len(r[i]) for r in rows) for i in range(4)]
    return [" ".join(c.ljust(w) for c, w in zip(r[:4], widths))
            + " " + r[4] for r in rows]
