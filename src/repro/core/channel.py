"""Delayed-delivery message channels for the tick-based WAN simulator.

A channel is a ring buffer ``[Dmax, n, n, P]`` of payload vectors plus a
presence flag ``[Dmax, n, n]``; sender i's message to j written at arrival
slot ``(t + delay_ij) % Dmax``. All protocol payloads are designed to be
*monotone* (elementwise-max mergeable) — colliding deliveries merge into
the later state, which an omission-fault-tolerant protocol tolerates by
construction (DESIGN.md §8). The receive side folds arrivals into a
"latest state" matrix with elementwise max.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

NEG = -1.0  # "absent" payload fill


def make_channel(dmax: int, n: int, p: int, additive: bool = False
                 ) -> Dict[str, jax.Array]:
    fill = 0.0 if additive else NEG
    return {
        "buf": jnp.full((dmax, n, n, p), fill, jnp.float32),
        "flag": jnp.zeros((dmax, n, n), jnp.bool_),
        "fill": jnp.float32(fill),
    }


def send(ch: Dict[str, jax.Array], t: jax.Array, payload: jax.Array,
         delay_ticks: jax.Array, mask: jax.Array, additive: bool = False,
         drop: jax.Array | None = None) -> Dict[str, jax.Array]:
    """payload: [n, n, P] (sender, receiver, fields); delay_ticks: [n, n]
    int32 >= 1; mask: [n, n] bool — which (i, j) actually send this tick.
    drop: optional [n, n] bool — links the network scenario cuts this tick
    (netsim.link_drop); a dropped send is a silent omission, which the
    monotone-payload protocols tolerate by construction.
    Merging policy: elementwise max (monotone payloads) or add (counters)."""
    if drop is not None:
        mask = mask & ~drop
    dmax = ch["buf"].shape[0]
    n = payload.shape[0]
    slot = (t + jnp.clip(delay_ticks, 1, dmax - 1)) % dmax          # [n, n]
    ii, jj = jnp.meshgrid(jnp.arange(n), jnp.arange(n), indexing="ij")
    if additive:
        merged = jnp.where(mask[..., None], payload, 0.0)
        buf = ch["buf"].at[slot, ii, jj].add(merged)
    else:
        merged = jnp.where(mask[..., None], payload, NEG)
        buf = ch["buf"].at[slot, ii, jj].max(merged)
    flag = ch["flag"].at[slot, ii, jj].max(mask)
    return {"buf": buf, "flag": flag, "fill": ch["fill"]}


def deliver(ch: Dict[str, jax.Array], t: jax.Array
            ) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Pop slot t. Returns (channel, flags [n,n], payload [n,n,P])."""
    dmax = ch["buf"].shape[0]
    slot = t % dmax
    flags = ch["flag"][slot]
    payload = ch["buf"][slot]
    buf = ch["buf"].at[slot].set(ch["fill"])
    flag = ch["flag"].at[slot].set(False)
    return {"buf": buf, "flag": flag, "fill": ch["fill"]}, flags, payload


def fold_state(state: jax.Array, flags: jax.Array, payload: jax.Array
               ) -> jax.Array:
    """Merge arrivals into latest-state matrix [n, n, P] (receiver, sender)."""
    # payload is (sender, receiver, P) -> transpose to (receiver, sender, P)
    arr = jnp.swapaxes(payload, 0, 1)
    fl = jnp.swapaxes(flags, 0, 1)[..., None]
    return jnp.where(fl, jnp.maximum(state, arr), state)
