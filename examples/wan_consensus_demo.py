"""The paper's §5 in one script: run Mandator-Sporades and the baselines on
the simulated 5-region WAN; reproduce the Fig. 6 ordering and the Fig. 7
leader-crash recovery.

Sweeps go through the batched experiment engine: each protocol's rate grid
is one compiled vmapped program (see docs/ARCHITECTURE.md).

  PYTHONPATH=src python examples/wan_consensus_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs.smr import SMRConfig
from repro.core.experiment import SweepSpec, run_sweep
from repro.core.netsim import FaultSchedule


def main() -> None:
    cfg = SMRConfig(sim_seconds=3.0)
    print("== best-case WAN (5 regions: Virginia, Ireland, Mumbai, "
          "São Paulo, Tokyo) ==")
    for proto, rate in (("mandator-sporades", 400_000),
                        ("mandator-paxos", 400_000),
                        ("multipaxos", 100_000),
                        ("epaxos", 10_000),
                        ("rabia", 1_000)):
        r = run_sweep(proto, cfg, SweepSpec(rates=(rate,)))[0]
        print(f" {proto:20s} saturation ~{r['throughput']:8.0f} tx/s "
              f"@ {r['median_ms']:6.0f} ms median")

    print("\n== leader crash at t=1.5s (Fig. 7) ==")
    crash = np.full(5, np.inf)
    crash[0] = 1.5
    spec = SweepSpec(rates=(100_000,),
                     faults=(FaultSchedule(crash_time_s=crash),))
    for proto in ("mandator-sporades", "mandator-paxos"):
        r = run_sweep(proto, cfg, spec)[0]
        tl = "|".join(f"{x/1000:.0f}k" for x in r["timeline"])
        print(f" {proto:20s} [{tl}] tx/s per 500ms")


if __name__ == "__main__":
    main()
