"""Protocol-level tests of the paper's algorithms over the WAN simulator:
safety (agreement / single-history), liveness under crash faults and
asynchrony, Mandator availability, coin determinism. Property tests drive
random delay matrices and crash sets (hypothesis)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # degrade: only property tests skip
    HAVE_HYPOTHESIS = False

from repro.configs.smr import SMRConfig
from repro.core.coin import coin_table, common_coin_flip
from repro.core.harness import run_sim
from repro.scenarios import Crash, Scenario, library

CFG = SMRConfig(sim_seconds=2.0)


def _crash_at(times_s) -> Scenario:
    """Permanent crashes at per-replica times (inf = never) — the seed-era
    crash-schedule semantics as Scenario primitives."""
    return Scenario("crash", tuple(
        Crash(start_s=float(t), targets=(i,))
        for i, t in enumerate(times_s) if np.isfinite(t)))


def test_coin_determinism_and_range():
    a = [int(common_coin_flip(v, 5, seed=42)) for v in range(50)]
    b = [int(common_coin_flip(v, 5, seed=42)) for v in range(50)]
    assert a == b and all(0 <= x < 5 for x in a)
    t = np.asarray(coin_table(50, 5, seed=42))
    assert list(t) == a
    # unbiased-ish
    assert len(set(a)) == 5


def test_mandator_availability():
    """Every batch formed by a correct replica eventually completes
    (n-f votes) — availability of write(B)."""
    r = run_sim("mandator", CFG, rate_tx_s=20_000)
    assert r["throughput"] > 10_000
    assert r["median_ms"] < 1_000


def test_sporades_synchronous_commit():
    r = run_sim("mandator-sporades", CFG, rate_tx_s=20_000)
    assert r["throughput"] > 10_000
    assert r["async_frac"] == 0.0          # no spurious async entry
    assert r["views"] == 0                 # single stable view
    assert r["median_ms"] < 1_500


def _check_safety(cvc_all: np.ndarray):
    """cvc_all: [ticks, n, n] per-replica committed VCs over time.
    (1) monotone per replica; (2) any two committed VCs (across replicas
    and times) are comparable — single committed history."""
    t, n, _ = cvc_all.shape
    sub = cvc_all[:: max(1, t // 200)]
    flat = sub.reshape(-1, n)
    for i in range(n):
        col = cvc_all[:, i, :]
        assert (np.diff(col, axis=0) >= 0).all(), "per-replica VC not monotone"
    # pairwise comparability on the subsample: sort by sum then check chain
    order = np.argsort(flat.sum(axis=1))
    s = flat[order]
    prev = s[0]
    for row in s[1:]:
        assert (row >= prev).all(), "incomparable committed VCs (fork!)"
        prev = row


def test_sporades_safety_trace_synchronous():
    r = run_sim("mandator-sporades", CFG, rate_tx_s=20_000)
    _check_safety(np.asarray(r["cvc_all"]))


def test_sporades_liveness_under_leader_crash():
    crash = np.full(5, np.inf)
    crash[0] = 0.7              # L_0 dies mid-run
    r = run_sim("mandator-sporades", CFG, rate_tx_s=20_000,
                scenario=_crash_at(crash))
    tl = r["timeline"]
    # commits continue in the last quarter of the run (post-crash)
    assert tl[-1] > 0 or tl[-2] > 0
    assert r["views"] >= 1      # view changed away from the dead leader
    _check_safety(np.asarray(r["cvc_all"]))


def test_sporades_liveness_under_ddos():
    r = run_sim("mandator-sporades",
                SMRConfig(sim_seconds=3.0), rate_tx_s=50_000,
                scenario=library.get("paper-ddos", 3.0))
    assert r["throughput"] > 1_000         # stays live
    _check_safety(np.asarray(r["cvc_all"]))


def test_multipaxos_commits_and_crash_dip():
    r = run_sim("multipaxos", CFG, rate_tx_s=20_000)
    assert r["throughput"] > 10_000
    crash = np.full(5, np.inf)
    crash[0] = 0.7
    r2 = run_sim("multipaxos", CFG, rate_tx_s=20_000,
                 scenario=_crash_at(crash))
    assert r2["throughput"] < r["throughput"]   # crash hurts
    assert np.asarray(r2["timeline"])[-1] > 0   # but a new leader recovers


def test_mandator_paxos_matches_sporades_in_synchrony():
    """Paper's observation (1): same best-case performance."""
    a = run_sim("mandator-paxos", CFG, rate_tx_s=50_000)
    b = run_sim("mandator-sporades", CFG, rate_tx_s=50_000)
    assert abs(a["throughput"] - b["throughput"]) / b["throughput"] < 0.15


def _random_crash_case(seed):
    """Any minority crash set at random times: committed history stays
    fork-free."""
    rng = np.random.RandomState(seed)
    crash = np.full(5, np.inf)
    idx = rng.choice(5, size=2, replace=False)
    crash[idx] = rng.uniform(0.2, 1.5, size=2)
    r = run_sim("mandator-sporades", CFG, rate_tx_s=20_000,
                scenario=_crash_at(crash), seed=seed % 7)
    _check_safety(np.asarray(r["cvc_all"]))


if HAVE_HYPOTHESIS:
    @settings(max_examples=3, deadline=None)
    @given(st.integers(0, 2 ** 16 - 1))
    def test_sporades_safety_random_crashes(seed):
        _random_crash_case(seed)
else:
    def test_sporades_safety_random_crashes():
        """Degraded single-case variant (hypothesis not installed —
        pip install -r requirements-dev.txt for the property test)."""
        _random_crash_case(12345)


def test_baseline_models_sane():
    e = run_sim("epaxos", SMRConfig(sim_seconds=5.0), rate_tx_s=10_000)
    assert 1_000 < e["throughput"] < 20_000
    ra = run_sim("rabia", SMRConfig(sim_seconds=5.0), rate_tx_s=2_000)
    assert 100 < ra["throughput"] < 2_000
