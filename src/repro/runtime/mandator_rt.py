"""Mandator for the training control plane: vector-clock artifact rounds.

Each pod controller owns a chain of *artifact rounds* (gradient
accumulations, checkpoint shards, metric records). The dissemination layer
(payload movement: reduce-scatters, shard uploads) runs at network speed,
ahead of commit; the control plane exchanges only int round-vectors and
commits *cuts* — getClientRequests() of Algorithm 1, verbatim, with pods in
place of replicas and artifact rounds in place of request batches.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.obs.monitor import HostMonitor
from repro.obs.trace import HostTrace


@dataclass
class PodState:
    """One pod controller's view (lastCompletedRounds + own chain)."""
    pod: int
    n_pods: int
    own_round: int = 0
    awaiting: bool = False
    lcr: np.ndarray = field(default=None)
    votes: Dict[int, set] = field(default_factory=dict)

    def __post_init__(self):
        if self.lcr is None:
            self.lcr = np.zeros(self.n_pods, np.int64)


class MandatorRuntime:
    """In-process multi-pod instance (transport = direct calls; a real
    deployment swaps `broadcast` for RPC — the state machine is identical).
    Omission faults are injected by dropping deliveries (see tests)."""

    def __init__(self, n_pods: int):
        self.n = n_pods
        self.f = (n_pods - 1) // 2
        self.pods = [PodState(i, n_pods) for i in range(n_pods)]
        self.drop = np.zeros((n_pods, n_pods), bool)   # drop[i, j]: i->j lost
        # flight recorder (host-side twin of repro.obs, same taxonomy)
        self.trace = HostTrace()
        # health monitor: completions must be strictly in round order and
        # never repeat (chain order is Algorithm 1's core invariant)
        self.monitor = HostMonitor(n_pods)

    # ---- Algorithm 1 ------------------------------------------------------
    def write(self, pod: int, payload_ready: bool = True) -> Optional[int]:
        """new-Mandator-batch: announce round own_round+1 (payload assumed
        disseminated by the data plane — payload_ready is its ack)."""
        p = self.pods[pod]
        if p.awaiting or not payload_ready:
            return None
        r = p.own_round + 1
        p.awaiting = True
        p.votes[r] = set()
        self.trace.record("batch_create", r, who=pod, round=r, count=1)
        for j in range(self.n):
            if not self.drop[pod, j]:
                self._deliver_batch(pod, j, r)
        return r

    def _deliver_batch(self, owner: int, to: int, r: int) -> None:
        q = self.pods[to]
        q.lcr[owner] = max(q.lcr[owner], r - 1)
        if not self.drop[to, owner]:                   # Mandator-vote
            self.pods[owner].votes.setdefault(r, set()).add(to)
            self._check_complete(owner, r)

    def _check_complete(self, owner: int, r: int) -> None:
        p = self.pods[owner]
        if p.awaiting and r == p.own_round + 1 \
                and len(p.votes.get(r, ())) >= self.n - self.f:
            p.own_round = r
            p.awaiting = False
            p.lcr[owner] = r
            self.monitor.observe_completion(owner, r)
            self.trace.record("batch_stable", r, who=owner, round=r,
                              completed=1)

    # ---- consensus payload -------------------------------------------------
    def get_client_requests(self, pod: int) -> np.ndarray:
        """lastCompletedRounds — what the commit layer orders."""
        return self.pods[pod].lcr.copy()

    def committed_cut(self, cuts: List[np.ndarray]) -> np.ndarray:
        """Elementwise max of committed vector clocks (commit = monotone)."""
        out = np.zeros(self.n, np.int64)
        for c in cuts:
            out = np.maximum(out, c)
        return out
