"""Persistent XLA compilation cache + process-wide compile accounting.

BENCH_core.json says every fig suite is >=95% XLA compile time — execution
is essentially free since the batched-sweep work, so compilation is the
wall. This module makes compilation a **once-ever** cost and makes that
claim *measurable*:

1. ``enable()`` / ``ensure()`` pin the JAX persistent compilation cache to
   a repo-local directory (``JAX_COMPILATION_CACHE_DIR`` overrides), with
   the size/compile-time thresholds dropped to zero so every sweep program
   is cached. Repeat processes — CI jobs, pytest re-runs, benchmark
   re-runs — then pay XLA compile once ever: the second process *traces*
   (cheap) but loads the executable from disk instead of recompiling.
   ``experiment.run_sweep``, ``benchmarks/run.py``, the demo, and the
   tier-1 conftest fixture all route through here.

2. ``stats()`` / ``delta()`` account for what compilation actually
   happened, from ``jax.monitoring`` events: persistent-cache hits and
   misses, true backend-compile seconds, and the compile seconds a hit
   saved. ``experiment.compile_report()`` joins these counters with
   per-protocol trace counts and program signatures; ``benchmarks/run.py``
   snapshots per-suite deltas into BENCH_core.json, and
   tests/test_compile_cache.py uses them as the oracle that a warm-cache
   process compiles ~nothing.

Opt-outs: ``REPRO_COMPILE_CACHE=0`` disables ``ensure()`` (the lazy
auto-enable); ``disable()`` turns the cache off at runtime (the
``no_persistent_cache`` pytest marker uses it).
"""
from __future__ import annotations

import os
import threading
from pathlib import Path
from typing import Dict, Optional

import jax

# listener API has no public alias in this jax version
from jax._src import monitoring as _monitoring

DISABLE_ENV = "REPRO_COMPILE_CACHE"  # set to "0" to opt out of ensure()

_EVENT_HIT = "/jax/compilation_cache/cache_hits"
_EVENT_MISS = "/jax/compilation_cache/cache_misses"
_DUR_BACKEND_COMPILE = "/jax/core/compile/backend_compile_duration"
_DUR_SAVED = "/jax/compilation_cache/compile_time_saved_sec"
_DUR_RETRIEVAL = "/jax/compilation_cache/cache_retrieval_time_sec"

_lock = threading.Lock()
# explicit_off: disable() was called — ensure() must not silently undo it
_state: Dict = {"enabled": False, "dir": None, "explicit_off": False}

STAT_KEYS = ("persistent_cache_hits", "persistent_cache_misses",
             "backend_compile_s", "compile_saved_s", "cache_retrieval_s")
_counters: Dict[str, float] = dict.fromkeys(STAT_KEYS, 0.0)


def _on_event(event: str, **kw) -> None:
    with _lock:
        if event == _EVENT_HIT:
            _counters["persistent_cache_hits"] += 1
        elif event == _EVENT_MISS:
            _counters["persistent_cache_misses"] += 1


def _on_duration(event: str, duration_secs: float, **kw) -> None:
    with _lock:
        if event == _DUR_BACKEND_COMPILE:
            _counters["backend_compile_s"] += duration_secs
        elif event == _DUR_SAVED:
            # jax reports saved = (estimated compile time) - (retrieval
            # cost) per hit, which goes NEGATIVE for cheap programs whose
            # retrieval costs more than the compile it skipped — summing
            # raw deltas made whole suites report negative savings
            # (BENCH_core.json channel: -0.126s). A hit never *costs*
            # compile time (retrieval is accounted separately under
            # cache_retrieval_s), so clamp per event.
            _counters["compile_saved_s"] += max(duration_secs, 0.0)
        elif event == _DUR_RETRIEVAL:
            _counters["cache_retrieval_s"] += duration_secs


_monitoring.register_event_listener(_on_event)
_monitoring.register_event_duration_secs_listener(_on_duration)


def stats() -> Dict[str, float]:
    """Cumulative process-wide compile accounting: persistent-cache
    hits/misses (counts) and backend-compile / compile-saved /
    cache-retrieval wall-clock (seconds). Counts every jit in the
    process, not just sweep programs — snapshot + ``delta`` to scope."""
    with _lock:
        out = dict(_counters)
    out["persistent_cache_hits"] = int(out["persistent_cache_hits"])
    out["persistent_cache_misses"] = int(out["persistent_cache_misses"])
    return out


def delta(since: Dict[str, float]) -> Dict[str, float]:
    """Stats accumulated since a previous ``stats()`` snapshot."""
    now = stats()
    return {k: type(now[k])(now[k] - since.get(k, 0)) for k in STAT_KEYS}


def reset_stats() -> None:
    with _lock:
        for k in _counters:
            _counters[k] = 0.0


def default_cache_dir() -> Path:
    """``JAX_COMPILATION_CACHE_DIR`` if set; else ``<repo>/.jax_cache``
    when running from a source checkout; else a per-user cache dir."""
    env = os.environ.get("JAX_COMPILATION_CACHE_DIR")
    if env:
        return Path(env)
    root = Path(__file__).resolve().parents[3]
    if (root / "pyproject.toml").exists():
        return root / ".jax_cache"
    return Path.home() / ".cache" / "mandator_repro_jax"


def enable(cache_dir: Optional[os.PathLike | str] = None) -> Path:
    """Enable the persistent compilation cache at ``cache_dir`` (default:
    ``default_cache_dir()``). Idempotent; switching directories resets the
    in-memory cache handle so the new directory takes effect."""
    from jax._src import compilation_cache as _cc
    path = Path(cache_dir) if cache_dir is not None else default_cache_dir()
    path.mkdir(parents=True, exist_ok=True)
    changed = (not _state["enabled"]) or _state["dir"] != path
    jax.config.update("jax_enable_compilation_cache", True)
    jax.config.update("jax_compilation_cache_dir", str(path))
    # the sweep programs are modest in bytes but expensive to build: cache
    # every executable, no matter how small or fast it compiled
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    if changed:
        _cc.reset_cache()
    _state.update(enabled=True, dir=path, explicit_off=False)
    return path


def disable() -> None:
    """Turn the persistent cache off for subsequent compilations (already
    jitted executables stay live). ``enable()`` turns it back on."""
    from jax._src import compilation_cache as _cc
    jax.config.update("jax_enable_compilation_cache", False)
    _cc.reset_cache()
    _state.update(enabled=False, explicit_off=True)


def enabled() -> bool:
    return bool(_state["enabled"])


def cache_dir() -> Optional[Path]:
    """The active cache directory, or None when disabled."""
    return _state["dir"] if _state["enabled"] else None


def program_dir() -> Optional[Path]:
    """Directory for serialized *programs* (``jax.export`` blobs of traced
    sweep computations), under the active cache dir. The XLA cache above
    skips backend compilation on warm runs; the program store additionally
    skips per-process tracing + lowering — together a warm process goes
    straight from disk to execution. None when the cache is disabled."""
    d = cache_dir()
    if d is None:
        return None
    p = Path(d) / "programs"
    p.mkdir(parents=True, exist_ok=True)
    return p


_fingerprint: Optional[str] = None


def source_fingerprint() -> str:
    """Hash of everything that can invalidate a serialized program: the
    jax/jaxlib versions, the backend platform, and the full source of
    ``src/repro`` (any edit to the simulator must rebuild programs — the
    blob captures the traced computation, not the Python that built it).
    Computed once per process (~milliseconds)."""
    global _fingerprint
    if _fingerprint is None:
        import hashlib

        import jaxlib
        h = hashlib.sha256()
        h.update(jax.__version__.encode())
        h.update(jaxlib.__version__.encode())
        h.update(jax.default_backend().encode())
        root = Path(__file__).resolve().parents[1]  # src/repro
        for f in sorted(root.rglob("*.py")):
            h.update(str(f.relative_to(root)).encode())
            h.update(f.read_bytes())
        _fingerprint = h.hexdigest()
    return _fingerprint


def ensure() -> Optional[Path]:
    """Lazy default: enable the cache at ``default_cache_dir()`` unless
    the process opted out (``REPRO_COMPILE_CACHE=0``) or a caller already
    configured it. ``experiment.run_sweep`` calls this on every sweep so
    any entry point — benchmarks, demo, tests, library use — pays XLA
    compile once ever without explicit setup. Respects an explicit
    ``disable()`` — only ``enable()`` turns the cache back on."""
    if os.environ.get(DISABLE_ENV) == "0" or _state["explicit_off"]:
        return _state["dir"] if _state["enabled"] else None
    if not _state["enabled"]:
        enable()
    return _state["dir"]
