"""SMR simulation harness: drives a protocol over the WAN sim and produces
the paper's metrics (throughput, median/p99 execution latency, timelines).

Protocols:
  mandator-sporades  — Alg 1 + Algs 2/3 (full tick-level state machines)
  mandator-paxos     — Alg 1 + Multi-Paxos ordering the vector clock
  multipaxos         — monolithic Multi-Paxos (batches inside consensus)
  mandator           — dissemination layer alone (completion throughput)
  epaxos / rabia     — analytic baselines (see docstrings in epaxos.py/rabia.py)
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.smr import SMRConfig
from repro.core import mandator, netsim, paxos, sporades
from repro.core.netsim import FaultSchedule


@partial(jax.jit, static_argnames=("protocol", "cfg", "n_ticks"))
def _run_scan(protocol: str, cfg: SMRConfig, n_ticks: int,
              rate_per_tick: jax.Array, env: Dict, seed: int = 0):
    uses_mandator = protocol in ("mandator-sporades", "mandator-paxos",
                                 "mandator")
    st = {}
    if uses_mandator:
        st["m"] = mandator.init_state(cfg, n_ticks)
    if protocol == "mandator-sporades":
        st["s"] = sporades.init_state(cfg, n_ticks)
    if protocol in ("mandator-paxos", "multipaxos"):
        st["p"] = paxos.init_state(cfg, n_ticks,
                                   mandator_mode=(protocol == "mandator-paxos"))
    base_key = jax.random.PRNGKey(seed)

    def step(carry, t):
        key = jax.random.fold_in(base_key, t)
        out = {}
        if uses_mandator:
            carry = dict(carry)
            carry["m"] = mandator.tick(carry["m"], t, key, env, cfg,
                                       rate_per_tick)
            lcr = mandator.get_client_requests(carry["m"])
            out["own_round"] = carry["m"]["own_round"]
        if protocol == "mandator-sporades":
            carry["s"] = sporades.tick(carry["s"], t, env, cfg, lcr)
            out["cvc"] = jnp.max(carry["s"]["cvc"], axis=0)
            out["cvc_all"] = carry["s"]["cvc"]
            out["commit_key"] = carry["s"]["commit_key"]
            out["is_async"] = carry["s"]["is_async"]
            out["v_cur"] = carry["s"]["v_cur"]
        elif protocol == "mandator-paxos":
            carry["p"] = paxos.tick(carry["p"], t, key, env, cfg,
                                    rate_per_tick, True, lcr=lcr)
            out["cvc"] = jnp.max(carry["p"]["cvc"], axis=0)
        elif protocol == "multipaxos":
            carry = dict(carry)
            carry["p"] = paxos.tick(carry["p"], t, key, env, cfg,
                                    rate_per_tick, False)
            out["committed_slot"] = carry["p"]["committed_slot"]
        return carry, out

    st, trace = jax.lax.scan(step, st, jnp.arange(n_ticks, dtype=jnp.int32))
    return st, trace


def _weighted_quantile(vals: np.ndarray, weights: np.ndarray, q: float) -> float:
    if len(vals) == 0 or weights.sum() <= 0:
        return float("nan")
    order = np.argsort(vals)
    v, w = vals[order], weights[order]
    cum = np.cumsum(w) / w.sum()
    return float(v[np.searchsorted(cum, q, side="left").clip(0, len(v) - 1)])


def _batch_metrics(cfg: SMRConfig, create_t, arr_mean, count, commit_t,
                   warmup_frac=0.15, bucket_ms=500.0) -> Dict:
    """Post-hoc metrics over batch records (ticks -> ms via cfg.tick_ms)."""
    n_ticks = int(cfg.sim_seconds * 1000 / cfg.tick_ms)
    ok = np.isfinite(commit_t) & (count > 0) & np.isfinite(create_t)
    lat_ms = (commit_t - arr_mean) * cfg.tick_ms
    w0 = warmup_frac * n_ticks
    in_win = ok & (commit_t >= w0)
    win_s = (n_ticks - w0) * cfg.tick_ms / 1000.0
    tput = float(count[in_win].sum() / win_s) if win_s > 0 else 0.0
    med = _weighted_quantile(lat_ms[in_win], count[in_win], 0.5)
    p99 = _weighted_quantile(lat_ms[in_win], count[in_win], 0.99)
    nbuck = int(np.ceil(n_ticks * cfg.tick_ms / bucket_ms))
    timeline = np.zeros(nbuck)
    b = (commit_t[ok] * cfg.tick_ms / bucket_ms).astype(int).clip(0, nbuck - 1)
    np.add.at(timeline, b, count[ok])
    timeline /= bucket_ms / 1000.0
    return {"throughput": tput, "median_ms": med, "p99_ms": p99,
            "timeline": timeline, "committed": float(count[ok].sum())}


def _vc_commit_ticks(cvc_trace: np.ndarray, n: int, r_max: int) -> np.ndarray:
    """cvc_trace: [ticks, n] monotone. commit tick of batch (k, r) for
    r in 1..r_max -> [n, r_max] (inf if never)."""
    out = np.full((n, r_max), np.inf)
    for k in range(n):
        col = cvc_trace[:, k]
        rs = np.arange(1, r_max + 1)
        idx = np.searchsorted(col, rs, side="left")
        valid = idx < len(col)
        out[k, valid] = idx[valid]
    return out


def run_sim(protocol: str, cfg: SMRConfig, rate_tx_s: float,
            faults: Optional[FaultSchedule] = None, seed: int = 0) -> Dict:
    faults = faults or FaultSchedule()
    env = netsim.build_env(cfg, faults)
    n_ticks = env["n_ticks"]
    n = cfg.n_replicas
    rate_per_tick = jnp.float32(rate_tx_s * cfg.tick_ms / 1000.0 / n)

    if protocol == "epaxos":
        from repro.core.epaxos import run_epaxos_model
        return run_epaxos_model(cfg, rate_tx_s, faults)
    if protocol == "rabia":
        from repro.core.rabia import run_rabia_model
        return run_rabia_model(cfg, rate_tx_s, faults)

    st, trace = _run_scan(protocol, cfg, int(n_ticks), rate_per_tick, env,
                          seed)
    trace = jax.tree.map(np.asarray, trace)
    result: Dict = {"protocol": protocol, "rate": rate_tx_s}

    if protocol == "mandator":
        # dissemination completion = "commit" for availability accounting
        wl = jax.tree.map(np.asarray, st["m"]["wl"])
        cvc = trace["own_round"]                       # [ticks, n]
        commit_ticks = _vc_commit_ticks(cvc, n, wl["batch_count"].shape[1])
        result.update(_batch_metrics(
            cfg, np.asarray(wl["batch_create_t"]),
            np.asarray(wl["batch_arr_mean"]),
            np.asarray(wl["batch_count"]),
            np.concatenate([np.full((n, 1), np.inf), commit_ticks], axis=1)[
                :, :wl["batch_count"].shape[1]]))
        return result

    if protocol in ("mandator-sporades", "mandator-paxos"):
        wl = jax.tree.map(np.asarray, st["m"]["wl"])
        cvc = trace["cvc"]                             # [ticks, n]
        commit_ticks = _vc_commit_ticks(cvc, n, wl["batch_count"].shape[1])
        # batch r commits with VC >= r; index r-1 in arrays is round r? --
        # rounds are 1-based; array column r holds round r (col 0 unused).
        result.update(_batch_metrics(
            cfg, np.asarray(wl["batch_create_t"]),
            np.asarray(wl["batch_arr_mean"]),
            np.asarray(wl["batch_count"]),
            np.concatenate([np.full((n, 1), np.inf), commit_ticks], axis=1)[
                :, :wl["batch_count"].shape[1]]))
        if protocol == "mandator-sporades":
            result["async_frac"] = float(trace["is_async"].mean())
            result["views"] = int(trace["v_cur"].max())
            result["cvc_all"] = trace["cvc_all"]
            result["commit_key"] = trace["commit_key"]
        return result

    if protocol == "multipaxos":
        wl = jax.tree.map(np.asarray, st["p"]["wl"])
        cs = trace["committed_slot"]                   # [ticks, n] per leader
        commit_ticks = _vc_commit_ticks(cs, n, wl["batch_count"].shape[1])
        result.update(_batch_metrics(
            cfg, np.asarray(wl["batch_create_t"]),
            np.asarray(wl["batch_arr_mean"]),
            np.asarray(wl["batch_count"]),
            np.concatenate([np.full((n, 1), np.inf), commit_ticks], axis=1)[
                :, :wl["batch_count"].shape[1]]))
        return result

    raise ValueError(protocol)
