"""Lower a declarative Workload to fixed-shape windowed rate tables.

Mirrors scenarios/compile.py: the union of every primitive's tick edges
cuts the run into W maximal windows over which the rate table is constant;
``lower`` paints each primitive onto the rows it covers (in Workload
order) and emits, as plain numpy:

  win_start[W]           first tick of each window (win_start[0] == 0)
  win_of_tick[n_ticks]   tick -> window row (precomputed, exact)
  rate_of[W, n]          per-origin rate multiplier (1.0 = uniform share
                         of the sweep rate — the seed-era baseline)
  closed[()]             1.0 if the workload is closed-loop, else 0.0
  think_ticks[()]        closed-loop think time (1.0 when open)
  cap[()]                closed-loop per-origin outstanding cap
                         (effectively unbounded when open)

Padding to a common ``pad_windows`` (repeat-last-row; padded rows are
never read because ``win_of_tick`` only indexes real windows) is what
lets heterogeneous workloads stack leaf-wise and vmap through
``experiment.run_sweep`` as a third grid axis of ONE compiled program.

``is_trivial`` detects the all-ones open-loop table (a bare
``PoissonOpen()``): trivial grids take a static fast path in
``workload.arrive`` that is instruction-identical to the seed-era scalar
broadcast, which is what keeps the fig 6-9 artifacts byte-identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.configs.smr import SMRConfig
from repro.workloads.primitives import PoissonOpen, Workload

# float32 "unbounded" outstanding cap for open-loop lanes stacked into a
# closed-mode program (finite so cap arithmetic can never produce inf-inf)
OPEN_CAP = 1e18

Tables = Dict[str, np.ndarray]


@dataclass(frozen=True)
class WorkloadMode:
    """Static (trace-time) shape of a sweep's workload axis. ``trivial``
    selects the seed-identical scalar-broadcast path; ``closed`` compiles
    the closed-loop machinery (population arrivals + in-flight feedback)
    into the scan. A grid mixing open and closed workloads runs in closed
    mode and selects per-lane behavior on the ``closed`` table leaf."""
    trivial: bool = True
    closed: bool = False


TRIVIAL_MODE = WorkloadMode()


def _sim_ticks(cfg: SMRConfig) -> int:
    # keep in sync with netsim.sim_ticks (workloads sit below core in the
    # layering, like scenarios)
    return int(cfg.sim_seconds * 1000 / cfg.tick_ms)


def _win_starts(cfg: SMRConfig, wl: Workload) -> np.ndarray:
    n_ticks = _sim_ticks(cfg)
    edges = {0}
    for shape in wl.shapes:
        edges.update(int(e) for e in shape.edges(cfg, n_ticks))
    return np.array(sorted(e for e in edges if 0 <= e < n_ticks), np.int64)


def n_windows(cfg: SMRConfig, wl) -> int:
    """Window count of the lowered workload (for cross-workload padding)."""
    return len(_win_starts(cfg, as_workload(wl)))


def lower(cfg: SMRConfig, wl, pad_windows: Optional[int] = None) -> Tables:
    wl = as_workload(wl)
    n = cfg.n_replicas
    n_ticks = _sim_ticks(cfg)
    win_start = _win_starts(cfg, wl)
    w = len(win_start)
    tab: dict = {
        # lint: allow(dtype-hygiene): the paint buffer is f64 so
        # primitive stacking is bit-stable; cast to f32 below
        "rate_of": np.ones((w, n), np.float64),
        "closed": False,
        "think_ticks": 1.0,
        "cap": OPEN_CAP,
    }
    for shape in wl.shapes:
        shape.paint(cfg, n_ticks, win_start, tab)
    rate_of = tab["rate_of"].astype(np.float32)
    if pad_windows is not None:
        if pad_windows < w:
            raise ValueError(f"pad_windows={pad_windows} < {w} real windows")
        rate_of = np.pad(rate_of, ((0, pad_windows - w), (0, 0)),
                         mode="edge")
    return {
        "win_start": win_start,
        "win_of_tick": (np.searchsorted(win_start, np.arange(n_ticks),
                                        side="right") - 1).astype(np.int32),
        "rate_of": rate_of,
        "closed": np.float32(1.0 if tab["closed"] else 0.0),
        "think_ticks": np.float32(tab["think_ticks"]),
        "cap": np.float32(tab["cap"]),
    }


def is_trivial(tab: Tables) -> bool:
    """True iff the lowered table is the seed-era baseline: open-loop,
    single window, every origin at exactly its uniform share. Judge the
    UNPADDED lowering: canonical-signature padding widens the window axis
    without changing semantics, so the sweep engine decides the static
    mode before padding (experiment._lower)."""
    return (float(tab["closed"]) == 0.0
            and tab["rate_of"].shape[0] == 1
            and bool(np.all(tab["rate_of"] == 1.0)))


def mode_of(tabs) -> WorkloadMode:
    """The static mode a grid of lowered workloads compiles under."""
    return WorkloadMode(
        trivial=all(is_trivial(t) for t in tabs),
        closed=any(float(t["closed"]) > 0 for t in tabs),
    )


def as_workload(obj) -> Workload:
    """Normalize None / Workload to a Workload."""
    if obj is None:
        return Workload("poisson-open", (PoissonOpen(),))
    if isinstance(obj, Workload):
        return obj
    raise TypeError(f"expected Workload or None, got {type(obj)}")
