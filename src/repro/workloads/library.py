"""Curated workload library — the traffic shapes the workload matrix runs.

Windows are placed at fractions of ``sim_s`` so the same shapes stress a
2-second smoke run and a 10-second sweep alike. ``workloads(sim_s)``
returns an ordered name -> Workload dict; ``get(name, sim_s)`` fetches one.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.workloads.primitives import (
    ClosedLoop,
    DiurnalRamp,
    FlashCrowd,
    OnOffBurst,
    PoissonOpen,
    RegionSkew,
    Workload,
)


def _geo_weights(n: int) -> tuple:
    """A plausibly-skewed planet: population decays by region index."""
    w = 0.5 ** np.arange(n)
    return tuple(float(x) for x in w / w.sum())


def workloads(sim_s: float, n: int = 5) -> Dict[str, Workload]:
    return {
        # the paper's §5.2 baseline — compiles to the all-ones fast path
        "poisson-open": Workload("poisson-open", (PoissonOpen(),)),
        # everyone bursts together: 40% duty at 2.5x, silent otherwise
        "onoff-burst": Workload("onoff-burst", (
            OnOffBurst(period_s=0.25 * sim_s, duty=0.4, on_scale=2.5,
                       off_scale=0.0),)),
        # one day/night cycle across the run, staircased at 16 steps
        "diurnal": Workload("diurnal", (
            DiurnalRamp(period_s=sim_s, low=0.25, high=1.75,
                        step_s=sim_s / 16),)),
        # Mumbai goes viral mid-run: 6x spike, exponential cool-down
        "flash-crowd": Workload("flash-crowd", (
            FlashCrowd(at_s=0.4 * sim_s, duration_s=0.15 * sim_s,
                       magnitude=6.0, targets=(2 % n,),
                       decay_s=0.2 * sim_s),)),
        # WPaxos-style locality: 80% of load on one region, hotspot
        # migrating to the next region four times over the run
        "region-skew": Workload("region-skew", (
            RegionSkew(hot_frac=0.8, hot=(0,), migrate_s=0.25 * sim_s),)),
        # Atlas-style closed loop: uniform client pools, 50ms think time
        "closed-loop": Workload("closed-loop", (
            ClosedLoop(think_ms=50.0, cap=4000.0),)),
        # geo-placed closed loop: population-skewed pools + bursty rhythm
        "skewed-closed": Workload("skewed-closed", (
            OnOffBurst(period_s=0.5 * sim_s, duty=0.6, on_scale=1.5,
                       off_scale=0.5),
            ClosedLoop(think_ms=50.0, cap=4000.0,
                       placement=_geo_weights(n)),)),
    }


NAMES = tuple(workloads(1.0))


def get(name: str, sim_s: float, n: int = 5) -> Workload:
    lib = workloads(sim_s, n)
    if name not in lib:
        raise KeyError(
            f"unknown workload {name!r}; known: {', '.join(lib)}")
    return lib[name]
