"""Config system: model configs, input-shape configs, arch registry.

Every assigned architecture has one ``configs/<id>.py`` exporting ``CONFIG``.
``reduced()`` derives a CPU-smoke-testable config of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    every: int = 1            # MoE MLP on layers where (layer_idx % every == every-1)
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    dense_d_ff: int = 0            # width of the parallel dense FFN
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"       # "mamba" | "xlstm"
    d_state: int = 16         # mamba SSM state per channel
    d_conv: int = 4
    expand: int = 2
    # xlstm-only: sLSTM block every `slstm_every` layers (others are mLSTM)
    slstm_every: int = 8
    chunk: int = 128          # chunked-scan block length


@dataclass(frozen=True)
class CrossAttnConfig:
    every: int = 5            # cross-attn layer every k layers (vlm)
    n_mem_tokens: int = 1601  # precomputed vision-patch embeddings (stub frontend)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    cross_attn: Optional[CrossAttnConfig] = None
    # hybrid: one attention layer per `attn_every` layers, the rest SSM.
    # attn_every == 1 -> all attention; attn_every == 0 -> no attention (pure ssm)
    attn_every: int = 1
    # audio stub: inputs are precomputed frame embeddings, not token ids
    embed_inputs: bool = True
    notes: str = ""

    # ---- derived ----------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind: attn | mamba | mlstm | slstm."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm" and self.ssm is not None:
                if self.ssm.kind == "xlstm":
                    k = "slstm" if (i % self.ssm.slstm_every == self.ssm.slstm_every - 1) else "mlstm"
                else:
                    k = "mamba"
            elif self.family == "hybrid":
                # jamba-style 1:(attn_every-1) interleave; attention sits mid-period
                k = "attn" if (i % self.attn_every == self.attn_every // 2) else "mamba"
            else:
                k = "attn"
            kinds.append(k)
        return tuple(kinds)

    def layer_has_moe(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every == self.moe.every - 1)

    def layer_has_cross_attn(self, i: int) -> bool:
        return (self.cross_attn is not None
                and i % self.cross_attn.every == self.cross_attn.every - 1)

    @property
    def block_period(self) -> int:
        """Smallest repeating super-block period (for scan-over-layers)."""
        p = 1
        if self.family == "ssm" and self.ssm is not None and self.ssm.kind == "xlstm":
            p = self.ssm.slstm_every
        if self.family == "hybrid":
            p = self.attn_every
        if self.moe is not None:
            p = _lcm(p, self.moe.every)
        if self.cross_attn is not None:
            p = _lcm(p, self.cross_attn.every)
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        return p

    @property
    def sub_quadratic(self) -> bool:
        """Can serve 500k-token contexts (O(1)/O(s) state, not O(s) KV on every layer)."""
        return self.family in ("ssm", "hybrid")

    # ---- reduced smoke config --------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
        period = self.block_period
        n_layers = max(period, 2) if self.n_layers % 2 == 0 else period
        # keep the super-block structure intact; shrink everything else
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2), d_ff_expert=64,
                dense_d_ff=32 if self.moe.dense_residual else 0)
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=8, chunk=16)
        cross = None
        if self.cross_attn is not None:
            cross = dataclasses.replace(self.cross_attn, n_mem_tokens=7)
        n_kv = min(self.n_kv_heads, 2)
        n_h = max(2 * n_kv, 2)
        return dataclasses.replace(
            self, name=self.name + "-reduced", n_layers=n_layers,
            d_model=64, n_heads=n_h, n_kv_heads=n_kv, head_dim=16,
            d_ff=96 if self.d_ff else 0, vocab=256,
            moe=moe, ssm=ssm, cross_attn=cross)


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


# ---- input shapes ----------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                 # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention (skip for pure full-attention)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


# ---- parameter counting (for MODEL_FLOPS = 6·N·D roofline term) ------------

def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count from the config (embeddings + blocks + head)."""
    n = 0
    if cfg.embed_inputs:
        n += cfg.vocab * cfg.d_model
    if not cfg.tie_embeddings:
        n += cfg.vocab * cfg.d_model          # lm head
    kinds = cfg.layer_kinds()
    for i, kind in enumerate(kinds):
        n += cfg.d_model                      # norm1
        if kind == "attn":
            n += cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)   # qkv
            if cfg.qkv_bias:
                n += cfg.q_dim + 2 * cfg.kv_dim
            if cfg.qk_norm:
                n += 2 * cfg.head_dim
            n += cfg.q_dim * cfg.d_model      # out proj
        elif kind == "mamba":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            n += cfg.d_model * 2 * d_in           # in proj (x, z)
            n += d_in * s.d_conv + d_in           # conv w + b
            n += d_in * 2 * s.d_state             # w_bc
            n += d_in + d_in                      # w_dt [Di,1] + dt_bias
            n += d_in * s.d_state + d_in          # A_log, D
            n += d_in * cfg.d_model               # out proj
        elif kind == "mlstm":
            d_in = 2 * cfg.d_model
            n += cfg.d_model * 3 * d_in           # q,k,v (wide)
            n += 2 * (cfg.d_model * cfg.n_heads + cfg.n_heads)  # i,f gates
            n += cfg.d_model * d_in + d_in        # output gate
            n += d_in * cfg.d_model               # out proj
        elif kind == "slstm":
            d_in = 2 * cfg.d_model
            dh = d_in // cfg.n_heads
            n += 4 * (cfg.d_model * d_in + d_in)  # i,f,z,o projections
            n += 4 * cfg.n_heads * dh * dh        # recurrent head mixing
            n += d_in * cfg.d_model               # out proj
        if cfg.layer_has_cross_attn(i):
            n += cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * cfg.d_model
            n += cfg.d_model                  # cross norm
        # mlp
        if cfg.layer_has_moe(i):
            m = cfg.moe
            n += cfg.d_model                  # norm2
            per_exp = 3 * cfg.d_model * m.d_ff_expert   # swiglu: gate, up, down
            n += m.n_experts * per_exp if not active_only else m.top_k * per_exp
            n += cfg.d_model * m.n_experts               # router
            if m.dense_residual:
                n += 3 * cfg.d_model * m.dense_d_ff
        elif cfg.d_ff:
            n += cfg.d_model                  # norm2
            n += 3 * cfg.d_model * cfg.d_ff
    n += cfg.d_model                          # final norm
    return n
