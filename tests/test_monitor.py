"""Consensus health monitor (repro/obs/monitor + history): the OFF level
is bitwise inert for every scan protocol (monitoring must never perturb
the physics), the full monitor reports ZERO violations across the entire
curated scenario library and all six protocols, seeded violations each
trip exactly their own invariant counter, the commit-stall watchdog fires
on a frozen-leader cluster and stays silent when views rotate, and the
BENCH_history.jsonl ledger round-trips + gates regressions."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.smr import SMRConfig
from repro.core import netsim
from repro.core.experiment import ANALYTIC_PROTOCOLS, SweepSpec, run_sweep
from repro.core.harness import SCAN_PROTOCOLS, run_sim
from repro.obs import export, history, monitor
from repro.obs.monitor import MonitorLevel, VIOLATIONS, HostMonitor
from repro.obs.trace import TraceLevel
from repro.scenarios import Partition, Scenario
from repro.scenarios import library as scenario_library

SIM_S = 1.0
RATE = 50_000.0

# keys every scan protocol emits that are plain metric arrays (the mon
# keys are additions, not perturbations — asserted separately)
METRIC_KEYS = ("throughput", "median_ms", "p99_ms", "committed", "timeline",
               "origin_median_ms", "origin_p99_ms", "origin_timeline",
               "origin_lat_ms_timeline")

VIDX = {name: i for i, name in enumerate(VIOLATIONS)}


def _viol(r) -> np.ndarray:
    return np.asarray(r["mon"]["viol"])


# ----------------------------------------- off == monitored, bitwise -----

@pytest.mark.parametrize("protocol", SCAN_PROTOCOLS)
@pytest.mark.parametrize("scenario_name", ["baseline", "paper-ddos"])
def test_monitor_level_off_is_bitwise_inert(protocol, scenario_name):
    """Every metric is bit-identical across off/gauges/full: the monitor
    only ever *reads* protocol state, and at OFF it is compiled out."""
    scen = None if scenario_name == "baseline" \
        else scenario_library.get("paper-ddos", SIM_S)
    outs = {}
    for level in MonitorLevel.ORDER:
        cfg = SMRConfig(sim_seconds=SIM_S, monitor_level=level)
        outs[level] = run_sim(protocol, cfg, RATE, scenario=scen)
    for level in (MonitorLevel.GAUGES, MonitorLevel.FULL):
        for k in METRIC_KEYS:
            np.testing.assert_array_equal(
                np.asarray(outs[MonitorLevel.OFF][k]),
                np.asarray(outs[level][k]),
                err_msg=f"{protocol}/{level}/{k}")
    # the monitored runs actually carry the additions
    assert "mon" not in outs[MonitorLevel.OFF]
    assert "viol" not in outs[MonitorLevel.GAUGES]["mon"]
    assert outs[MonitorLevel.FULL]["mon"]["viol"].shape == (len(VIOLATIONS),)


def test_off_config_is_the_default():
    assert SMRConfig().monitor_level == MonitorLevel.OFF


# ----------------------------------------- zero violations, full library --

def test_full_monitor_is_silent_across_scenario_library():
    """Every curated adversary × mandator-sporades, one batched sweep (one
    compiled program — scenarios are data): zero violations. The paper's
    robustness claim as an invariant, not a throughput number."""
    cfg = SMRConfig(sim_seconds=SIM_S, monitor_level=MonitorLevel.FULL)
    lib = scenario_library.scenarios(SIM_S, cfg.n_replicas)
    spec = SweepSpec(rates=(RATE,), scenarios=tuple(lib.values()))
    for name, r in zip(lib, run_sweep("mandator-sporades", cfg, spec)):
        counts = _viol(r)
        assert not counts.any(), \
            f"{name}: " + " ".join(f"{v}={counts[VIDX[v]]}"
                                   for v in VIOLATIONS if counts[VIDX[v]])
        v = monitor.verdict(r)
        assert v["ok"] and v["level"] == MonitorLevel.FULL


def test_full_monitor_is_silent_for_all_six_protocols():
    """Fault-free baseline, all six protocols (scan + analytic): every
    verdict is ok with an empty violation dict."""
    cfg = SMRConfig(sim_seconds=SIM_S, monitor_level=MonitorLevel.FULL)
    for proto in SCAN_PROTOCOLS:
        r = run_sim(proto, cfg, RATE)
        assert not _viol(r).any(), (proto, _viol(r))
    for proto, rate in zip(ANALYTIC_PROTOCOLS, (5_000.0, 800.0)):
        r = run_sweep(proto, cfg, SweepSpec(rates=(rate,)))[0]
        v = monitor.verdict(r)
        assert v is not None and v["ok"], (proto, v)


# ----------------------------------------- seeded violations, unit --------

def _views(n, cvc=None, commit_seq=None, view=None, formed=None,
           stable=None, commit_tot=0.0, pending=True, ring_occ=0.0,
           dropped=None):
    return {
        "cvc": None if cvc is None else jnp.asarray(cvc, jnp.int32),
        "commit_seq": None if commit_seq is None
        else jnp.asarray(commit_seq, jnp.int32),
        "view": None if view is None else jnp.asarray(view, jnp.int32),
        "formed": jnp.asarray(formed if formed is not None else [10] * n,
                              jnp.int32),
        "stable": jnp.asarray(stable if stable is not None else [0] * n,
                              jnp.int32),
        "commit_tot": jnp.float32(commit_tot),
        "pending": jnp.asarray(pending),
        "ring_occ": jnp.float32(ring_occ),
        "dropped": jnp.asarray(dropped if dropped is not None else [0] * n,
                               jnp.int32),
    }


class TestSeededViolations:
    """Each hand-built state mutation trips exactly its own counter."""
    N = 3

    def _run(self, views0, views1, cfg_kw=None, upd_kw=None, repeats=1):
        cfg = SMRConfig(n_replicas=self.N, sim_seconds=SIM_S,
                        monitor_level=MonitorLevel.FULL, **(cfg_kw or {}))
        env = netsim.build_env(cfg)
        grace = monitor.stall_grace_ticks(cfg, env)
        mon = monitor.init_monitor(cfg, 100, views0)
        for t in range(repeats):
            mon = monitor.update(mon, jnp.int32(t), cfg, env, views1, grace,
                                 **(upd_kw or {}))
        return np.asarray(mon["viol"])

    def _assert_only(self, counts, name, expect=None):
        assert counts[VIDX[name]] > 0, (name, counts)
        if expect is not None:
            assert counts[VIDX[name]] == expect, (name, counts)
        others = [v for v in VIOLATIONS if v != name]
        assert not any(counts[VIDX[v]] for v in others), (name, counts)

    def test_agreement(self):
        # two alive replicas committed divergent prefixes: neither VC
        # dominates the other
        z = np.zeros((self.N, self.N), np.int32)
        div = np.array([[2, 0, 0], [0, 2, 0], [0, 0, 0]], np.int32)
        counts = self._run(_views(self.N, cvc=z, formed=[2, 2, 0]),
                           _views(self.N, cvc=div, formed=[2, 2, 0]))
        self._assert_only(counts, "agreement", expect=1)

    def test_prefix_retraction(self):
        # a committed slot is mutated backwards: commit retracted
        ones = np.ones((self.N, self.N), np.int32)
        counts = self._run(_views(self.N, cvc=ones),
                           _views(self.N, cvc=np.zeros_like(ones)))
        self._assert_only(counts, "prefix", expect=1)

    def test_commit_once_phantom(self):
        # the cluster claims round 3 committed for origin 0 which only
        # ever formed 2 batches: phantom commit
        claim = np.tile(np.array([3, 0, 0], np.int32), (self.N, 1))
        counts = self._run(_views(self.N, cvc=np.zeros_like(claim),
                                  formed=[2, 2, 2]),
                           _views(self.N, cvc=claim, formed=[2, 2, 2]))
        self._assert_only(counts, "commit_once", expect=1)

    def test_view_monotone(self):
        counts = self._run(_views(self.N, view=[1, 1, 1]),
                           _views(self.N, view=[0, 1, 1]))
        self._assert_only(counts, "view_monotone", expect=1)

    def test_inflight_cap(self):
        wlt = {"cap": jnp.asarray([2.0] * self.N),
               "closed": jnp.asarray([1] * self.N, jnp.int32)}
        counts = self._run(
            _views(self.N), _views(self.N),
            upd_kw=dict(wlt=wlt, inflight=jnp.asarray([5.0, 0.0, 0.0]),
                        check_cap=True))
        self._assert_only(counts, "inflight_cap", expect=1)

    def test_stall_watchdog(self):
        # healthy cluster, work pending, commit_tot frozen: 8 armed ticks
        # against a 5-tick grace window -> exactly 3 violating ticks
        tick_ms = SMRConfig().tick_ms
        counts = self._run(
            _views(self.N), _views(self.N),
            cfg_kw=dict(monitor_stall_grace_ms=5.0 * tick_ms), repeats=8)
        self._assert_only(counts, "stall", expect=3)

    def test_progress_disarms_watchdog(self):
        cfg = SMRConfig(n_replicas=self.N, sim_seconds=SIM_S,
                        monitor_level=MonitorLevel.FULL,
                        monitor_stall_grace_ms=5.0 * SMRConfig().tick_ms)
        env = netsim.build_env(cfg)
        grace = monitor.stall_grace_ticks(cfg, env)
        mon = monitor.init_monitor(cfg, 100, _views(self.N))
        for t in range(20):  # a commit lands every 4th tick
            mon = monitor.update(mon, jnp.int32(t), cfg, env,
                                 _views(self.N, commit_tot=float(t // 4)),
                                 grace)
        assert not np.asarray(mon["viol"]).any()


# ----------------------------------------- seeded violations, e2e ---------

def test_frozen_leader_trips_stall_watchdog_only():
    """Multipaxos with its view-0 leader partitioned away and view changes
    disabled: the majority side is healthy + loaded but can never commit —
    the watchdog fires; every safety counter stays zero. With the default
    view timeout the views rotate and the same partition is silent."""
    sim_s = 1.5
    frozen = Scenario("frozen-leader", (
        Partition(start_s=0.0, end_s=sim_s,
                  groups=((0,), (1, 2, 3, 4))),))
    cfg = SMRConfig(sim_seconds=sim_s, monitor_level=MonitorLevel.FULL,
                    view_timeout_ms=10_000.0, monitor_stall_grace_ms=100.0)
    r = run_sim("multipaxos", cfg, 10_000.0, scenario=frozen)
    counts = _viol(r)
    assert counts[VIDX["stall"]] > 0, counts
    for name in ("agreement", "prefix", "commit_once", "view_monotone"):
        assert counts[VIDX[name]] == 0, (name, counts)
    # contrast: normal view timeout -> leadership rotates off the
    # partitioned replica and commits resume inside the (auto) grace
    cfg_ok = SMRConfig(sim_seconds=sim_s, monitor_level=MonitorLevel.FULL)
    r_ok = run_sim("multipaxos", cfg_ok, 10_000.0, scenario=frozen)
    assert not _viol(r_ok).any(), _viol(r_ok)


# ----------------------------------------- host-side re-check -------------

def test_check_cvc_trace_flags_mutated_slot():
    """Mutating one replica's committed VC in a clean trace flips exactly
    the agreement (divergence) and prefix (retraction) counters."""
    T, n = 20, 3
    base = np.cumsum(np.ones((T, n, n), np.int64), axis=0)  # all equal
    clean = monitor.check_cvc_trace(base)
    assert clean == {"agreement": 0, "prefix": 0}
    bad = base.copy()
    bad[10, 1] = [0, 99, 0]   # divergent AND a retraction vs t=9
    res = monitor.check_cvc_trace(bad)
    assert res["agreement"] >= 1
    assert res["prefix"] >= 1


def test_host_monitor_commit_once_and_clean_flow():
    hm = HostMonitor(3)
    cut = np.array([3, 2, 1])
    hm.observe_commit(0, view=1, rnd=1, cut=cut)
    hm.observe_commit(1, view=1, rnd=1, cut=cut)       # same slot, same cut
    assert hm.verdict()["ok"]
    hm.observe_commit(2, view=1, rnd=1, cut=np.array([9, 9, 9]))
    v = hm.verdict()
    assert not v["ok"] and "commit_once" in v["violations"]


def test_host_monitor_completion_order():
    hm = HostMonitor(2)
    hm.observe_completion(0, 1)
    hm.observe_completion(0, 2)
    assert hm.verdict()["ok"]
    hm.observe_completion(0, 2)                        # repeat -> once
    hm.observe_completion(0, 5)                        # gap -> prefix
    v = hm.verdict()
    assert v["violations"] == {"commit_once": 1, "prefix": 1}


def test_runtime_drivers_report_clean_verdicts():
    from repro.runtime.mandator_rt import MandatorRuntime
    from repro.runtime.sporades_rt import SporadesRuntime
    mrt = MandatorRuntime(5)
    for _ in range(4):
        for p in range(5):
            mrt.write(p)
    assert mrt.monitor.verdict()["ok"]
    srt = SporadesRuntime(5)
    for step in range(4):
        cuts = {i: mrt.get_client_requests(i) for i in range(5)}
        assert srt.commit_step(cuts) is not None
    assert srt.monitor.verdict()["ok"]


# ----------------------------------------- gauges + export ----------------

def test_gauges_flow_into_verdict_and_export():
    """Gauge counters flow out of the scan into the verdict and the
    Perfetto counter tracks, and the exported trace passes validation."""
    cfg = SMRConfig(sim_seconds=SIM_S, trace_level=TraceLevel.FULL,
                    monitor_level=MonitorLevel.FULL)
    r = run_sim("mandator-sporades", cfg, RATE)
    v = monitor.verdict(r)
    g = v["gauges"]
    assert 0.0 < g["ring_occ_max"] <= 1.0
    assert 0.0 < g["ring_occ_mean"] <= g["ring_occ_max"]
    assert g["dropped_sends"] == 0
    assert len(g["inflight_hwm"]) == cfg.n_replicas
    assert len(g["starved_max"]) == cfg.n_replicas
    assert g["stall_max_ticks"] >= 0
    trace = export.chrome_trace(r, cfg, "mandator-sporades")
    export.validate(trace)
    counters = [e for e in trace["traceEvents"] if e["ph"] == "C"]
    names = {e["name"] for e in counters}
    assert "ring occupancy" in names
    assert "dropped sends/s" in names
    occ = [e for e in counters if e["name"] == "ring occupancy"]
    assert max(e["args"]["occupancy"] for e in occ) > 0.0
    assert monitor.format_verdict(v).startswith("monitor OK")
    assert "health: monitor OK" in monitor.health_table(r)


def test_validate_rejects_bad_counter_args():
    cfg = SMRConfig(sim_seconds=SIM_S, trace_level=TraceLevel.FULL,
                    monitor_level=MonitorLevel.GAUGES)
    r = run_sim("mandator-sporades", cfg, RATE)
    trace = export.chrome_trace(r, cfg, "mandator-sporades")
    trace["traceEvents"].append({"ph": "C", "pid": 0, "tid": 2,
                                 "name": "bad", "ts": 0.0,
                                 "args": {"x": float("nan")}})
    with pytest.raises(ValueError, match="finite numeric"):
        export.validate(trace)


# ----------------------------------------- history ledger + gate ----------

def _suites(wall=1.0, ok=True, viol=None, error=None):
    s = {"wall_s": wall, "compile_s": 0.5, "run_s": 0.5,
         "xla_compile_s": 0.4, "cache_hits": 1, "cache_misses": 0,
         "cache_saved_s": 0.1, "traces": 2,
         "monitor": {"ok": ok, "violations": viol or {}, "level": "full",
                     "points": 4}}
    if error:
        s["error"] = error
    return {"fig6": s}


def test_history_round_trip_and_validation(tmp_path):
    p = tmp_path / "hist.jsonl"
    e1 = history.make_entry(_suites(wall=2.0), quick=True,
                            git_sha="abc", timestamp=100.0)
    history.append(p, e1)
    with p.open("a") as f:                     # ledger survives junk lines
        f.write("not json\n")
    e2 = history.make_entry(_suites(wall=2.1), quick=True,
                            git_sha="def", timestamp=200.0)
    history.append(p, e2)
    entries = history.load(p)
    assert len(entries) == 2
    assert history.latest(p)["git_sha"] == "def"
    with pytest.raises(ValueError, match="ok=True with violations"):
        history.validate_entry(
            history.make_entry(_suites(ok=True, viol={"stall": 3}),
                               quick=False))
    with pytest.raises(ValueError, match="wall_s"):
        history.validate_entry({"schema": 1, "git_sha": "x",
                                "timestamp": 0.0, "quick": False,
                                "suites": {"fig6": {}}})


def test_history_compare_gates(tmp_path):
    base = history.make_entry(_suites(wall=10.0), quick=False)
    # same wall: ok
    cur = history.make_entry(_suites(wall=10.0), quick=False)
    assert history.compare(base, cur)["fig6"]["status"] == "ok"
    # +20% wall: inside the 25% budget
    cur = history.make_entry(_suites(wall=12.0), quick=False)
    assert history.compare(base, cur)["fig6"]["status"] == "ok"
    # +30% wall: warn, with the ratio recorded
    cur = history.make_entry(_suites(wall=13.0), quick=False)
    row = history.compare(base, cur)["fig6"]
    assert row["status"] == "warn" and row["ratio"] == 1.3
    # monitor violation: fail, regardless of wall-clock
    cur = history.make_entry(_suites(wall=1.0, ok=False,
                                     viol={"agreement": 2}), quick=False)
    row = history.compare(base, cur)["fig6"]
    assert row["status"] == "fail" and row["violations"] == {"agreement": 2}
    # suite error: warn
    cur = history.make_entry(_suites(wall=1.0, error="ValueError"),
                             quick=False)
    assert history.compare(base, cur)["fig6"]["status"] == "warn"
    # no baseline: only its own monitor can fail it
    row = history.compare(None, cur)["fig6"]
    assert row["status"] == "warn"           # error still warns
    lines = history.format_compare(history.compare(base, cur))
    assert any("fig6" in ln for ln in lines)
    # entries are single JSON lines (the CI gate reads them back)
    p = tmp_path / "h.jsonl"
    history.append(p, history.make_entry(_suites(), quick=True))
    line = p.read_text().splitlines()[0]
    assert json.loads(line)["schema"] == history.SCHEMA_VERSION
