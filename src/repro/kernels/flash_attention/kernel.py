"""Flash attention Pallas-TPU kernel (causal, GQA).

Grid: (batch, q_head, num_q_blocks, num_kv_blocks) — the last dim is
sequential on TPU, so fp32 accumulator/m/l scratch persists across KV blocks
(online softmax). Block shapes are MXU-aligned (128 lanes). KV for query
head h comes from kv head ``h // (H/Kh)`` via the BlockSpec index map — GQA
without materializing repeated KV.

TPU adaptation vs the CUDA original: no warp-level shuffles — the online
softmax runs on [bq, bk] VREG tiles produced by MXU matmuls; HBM->VMEM
streaming is expressed by BlockSpecs, not cp.async.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, num_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    if causal:
        should_run = (ik * bk) <= (iq * bq + bq - 1)  # skip blocks above diag
    else:
        should_run = jnp.bool_(True)

    @pl.when(should_run)
    def _run():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        s = s * (1.0 / (q.shape[-1] ** 0.5))
        if causal:
            rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == num_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, bq: int = 128, bk: int = 128,
                         interpret: bool = False) -> jax.Array:
    """q: [B, H, Sq, D]; k, v: [B, Kh, Sk, D]. Returns [B, H, Sq, D]."""
    b, h, sq, d = q.shape
    kh, sk = k.shape[1], k.shape[2]
    assert h % kh == 0 and sq % bq == 0 and sk % bk == 0, (q.shape, k.shape)
    group = h // kh
    num_q, num_kv = sq // bq, sk // bk

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               num_kv=num_kv)
    return pl.pallas_call(
        kernel,
        grid=(b, h, num_q, num_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
