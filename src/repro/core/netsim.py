"""WAN network environment: per-pair delays, NIC egress serialization,
crash faults, and targeted-minority DDoS (the §5.5 generalized
delayed-view-change attack).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.smr import SMRConfig


@dataclass(frozen=True)
class FaultSchedule:
    """crash_time_s[i] — replica i stops at that time (inf = never).
    ddos: if enabled, every ``repick_s`` seconds a random minority set is
    attacked; their links gain ``attack_delay_ms`` each way."""
    crash_time_s: Optional[np.ndarray] = None
    ddos: bool = False
    ddos_attack_delay_ms: float = 800.0
    ddos_repick_s: float = 2.0
    ddos_seed: int = 7


def build_env(cfg: SMRConfig, faults: FaultSchedule) -> Dict[str, jnp.ndarray]:
    n = cfg.n_replicas
    delays = jnp.asarray(cfg.delays_ms() / cfg.tick_ms)        # [n,n] ticks
    crash = (jnp.full((n,), jnp.inf) if faults.crash_time_s is None
             else jnp.asarray(faults.crash_time_s * 1000.0 / cfg.tick_ms))
    ticks = int(cfg.sim_seconds * 1000 / cfg.tick_ms)
    if faults.ddos:
        # pre-generate the attacked minority per repick window
        rng = np.random.RandomState(faults.ddos_seed)
        f = (n - 1) // 2
        n_windows = int(np.ceil(cfg.sim_seconds / faults.ddos_repick_s)) + 1
        att = np.zeros((n_windows, n), np.bool_)
        for w in range(n_windows):
            att[w, rng.choice(n, size=f, replace=False)] = True
        attacked = jnp.asarray(att)
    else:
        attacked = jnp.zeros((1, n), jnp.bool_)
    return {
        "delays": delays,
        "crash_tick": crash,
        "attacked": attacked,
        "ddos_delay": jnp.float32(
            faults.ddos_attack_delay_ms / cfg.tick_ms if faults.ddos else 0.0),
        "repick_ticks": jnp.int32(max(1, int(
            faults.ddos_repick_s * 1000 / cfg.tick_ms))),
        "n_ticks": ticks,
        "bytes_per_tick": jnp.float32(
            cfg.nic_gbps * 1e9 / 8.0 * cfg.tick_ms / 1000.0),
        "cpu_req_per_tick": jnp.float32(
            cfg.tick_ms * 1000.0 / cfg.cpu_us_per_request),
    }


def alive(env, t) -> jax.Array:
    """[n] bool — replica has not crashed."""
    return t < env["crash_tick"]


def link_delay(env, t) -> jax.Array:
    """[n, n] delay in ticks including DDoS extra delay on attacked nodes."""
    w = jnp.minimum(t // env["repick_ticks"], env["attacked"].shape[0] - 1)
    att = env["attacked"][w]                                   # [n]
    extra = (att[:, None] | att[None, :]) * env["ddos_delay"]
    return env["delays"] + extra


def egress_delay(busy: jax.Array, t: jax.Array, bytes_out: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """NIC serialization. busy: [n] abs tick when NIC frees; bytes_out: [n,n]
    bytes sent this tick (serialized in receiver order). Returns
    (new_busy [n], extra_delay_ticks [n,n])."""
    # cumulative serialization time per receiver j (order: j ascending)
    # NOTE: env['bytes_per_tick'] is folded in by the caller.
    cum = jnp.cumsum(bytes_out, axis=1)
    start = jnp.maximum(busy, t.astype(jnp.float32))[:, None]
    finish = start + cum
    new_busy = start[:, 0] + cum[:, -1]
    return new_busy, finish - t.astype(jnp.float32)
