"""Fault-tolerant multi-pod training demo — the paper's technique as the
training control plane: Mandator vector-clock rounds + Sporades dual-mode
commit + elastic rescale after a pod crash.

  PYTHONPATH=src python examples/train_smr_cluster.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.launch.train import train
from repro.runtime.sporades_rt import SporadesRuntime
from repro.runtime.elastic import StragglerPolicy


def main() -> None:
    print("== 3-pod training; pod 2 crashes at step 10 (elastic replan) ==")
    out = train("smollm-135m", steps=30, batch=6, seq=32, n_pods=3,
                crash_pod_at=10, lr=2e-3, log_every=5)
    print(f"committed steps per controller: {out['commits']}")
    assert np.isfinite(out["losses"]).all()

    print("\n== Sporades commit under a straggling leader ==")
    s = SporadesRuntime(4, seed=1)
    s.set_straggler(s.leader(0))           # leader misses the deadline
    for step in range(5):
        cuts = {i: np.full(4, step) for i in range(4)}
        rec = s.commit_step(cuts)
        print(f" step {step}: commit={'-' if rec is None else rec.mode} "
              f"view={s.view}")

    print("\n== straggler deadline policy ==")
    pol = StragglerPolicy(deadline_ms=100)
    pods, fb = pol.decide({0: 20, 1: 35, 2: 48, 3: 900}, 4)
    print(f" on-time quorum {pods}, fallback={fb} "
          f"(pod 3 gradient dropped, update rescaled 4/3)")


if __name__ == "__main__":
    main()
